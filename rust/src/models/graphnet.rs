//! Interaction-Network-style GraphNet training step (Battaglia et al.
//! 2016) — the paper's "Other models" workload (§3): "the automap
//! prototype ... was able to discover simple manual strategies such as
//! input edge sharding that allow practitioners to begin experimentation
//! with larger graphs".
//!
//! Message passing: edge messages from gathered sender/receiver node
//! features, segment-sum aggregation back to nodes, node update MLP.

use crate::ir::autodiff::gradients;
use crate::ir::{ArgKind, DType, Func, GraphBuilder, TensorType, ValueId};

#[derive(Debug, Clone)]
pub struct GraphNetConfig {
    pub num_nodes: i64,
    pub num_edges: i64,
    pub node_dim: i64,
    pub hidden: i64,
    pub rounds: usize,
    pub training: bool,
}

impl GraphNetConfig {
    pub fn small() -> GraphNetConfig {
        GraphNetConfig {
            num_nodes: 64,
            num_edges: 256,
            node_dim: 32,
            hidden: 64,
            rounds: 2,
            training: true,
        }
    }
}

pub struct GraphNetModel {
    pub func: Func,
    /// The edge-feature input arg (the "input edge sharding" target).
    pub edges_arg: ValueId,
    pub params: Vec<ValueId>,
    pub loss: ValueId,
}

pub fn build_graphnet(cfg: &GraphNetConfig) -> GraphNetModel {
    let mut b = GraphBuilder::new("graphnet_update");
    let (n, e, f, hd) = (cfg.num_nodes, cfg.num_edges, cfg.node_dim, cfg.hidden);

    let nodes = b.arg("nodes", TensorType::f32(&[n, f]), ArgKind::Input);
    let edges = b.arg("edges", TensorType::f32(&[e, f]), ArgKind::Input);
    let senders = b.arg("senders", TensorType::new(DType::I32, &[e]), ArgKind::Input);
    let receivers = b.arg("receivers", TensorType::new(DType::I32, &[e]), ArgKind::Input);
    let target = b.arg("target", TensorType::f32(&[n, f]), ArgKind::Input);

    let mut params = Vec::new();
    let decl = |b: &mut GraphBuilder,
                params: &mut Vec<ValueId>,
                scope: &str,
                name: &str,
                dims: &[i64]| {
        b.push_scope(scope);
        let id = b.arg(format!("{scope}/{name}"), TensorType::f32(dims), ArgKind::Parameter);
        b.pop_scope();
        params.push(id);
        id
    };
    let mut round_params = Vec::new();
    for r in 0..cfg.rounds {
        let es = format!("round_{r}/edge_mlp");
        let ns = format!("round_{r}/node_mlp");
        let ew1 = decl(&mut b, &mut params, &es, "w1", &[f, hd]);
        let eb1 = decl(&mut b, &mut params, &es, "b1", &[hd]);
        let ew2 = decl(&mut b, &mut params, &es, "w2", &[hd, f]);
        let eb2 = decl(&mut b, &mut params, &es, "b2", &[f]);
        let nw1 = decl(&mut b, &mut params, &ns, "w1", &[f, hd]);
        let nb1 = decl(&mut b, &mut params, &ns, "b1", &[hd]);
        let nw2 = decl(&mut b, &mut params, &ns, "w2", &[hd, f]);
        let nb2 = decl(&mut b, &mut params, &ns, "b2", &[f]);
        round_params.push((ew1, eb1, ew2, eb2, nw1, nb1, nw2, nb2));
    }

    let mlp2 = |b: &mut GraphBuilder,
                x: ValueId,
                w1: ValueId,
                b1: ValueId,
                w2: ValueId,
                b2: ValueId| {
        let h = b.matmul(x, w1);
        let hty = b.ty(h).clone();
        let b1b = b.broadcast_to(b1, hty);
        let h = b.add(h, b1b);
        let a = b.gelu(h);
        let y = b.matmul(a, w2);
        let yty = b.ty(y).clone();
        let b2b = b.broadcast_to(b2, yty);
        b.add(y, b2b)
    };

    let mut node_state = nodes;
    let mut edge_state = edges;
    for r in 0..cfg.rounds {
        let (ew1, eb1, ew2, eb2, nw1, nb1, nw2, nb2) = round_params[r];
        b.push_scope(&format!("round_{r}"));
        // Edge update: message from sender/receiver node features + edge.
        let sent = b.gather(node_state, senders); // [E,F]
        let recv = b.gather(node_state, receivers);
        let su = b.add(sent, recv);
        let msg_in = b.add(su, edge_state);
        let msg = mlp2(&mut b, msg_in, ew1, eb1, ew2, eb2); // [E,F]
        // Node update: aggregate incoming messages.
        let agg = b.segment_sum(msg, receivers, n); // [N,F]
        let ni = b.add(node_state, agg);
        let upd = mlp2(&mut b, ni, nw1, nb1, nw2, nb2);
        node_state = b.add(node_state, upd);
        edge_state = msg;
        b.pop_scope();
    }

    let diff = b.sub(node_state, target);
    let sq = b.mul(diff, diff);
    let tot = b.reduce_sum(sq, vec![0, 1]);
    let loss = b.scale(tot, 1.0 / (n * f) as f64);

    if cfg.training {
        let grads = gradients(&mut b, loss, &params);
        for (i, &p) in params.iter().enumerate() {
            if let Some(g) = grads[i] {
                let step = b.scale(g, 1e-2);
                let p_new = b.sub(p, step);
                b.output(p_new);
            }
        }
    }
    b.output(loss);
    GraphNetModel { func: b.finish(), edges_arg: edges, params, loss }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verify::verify;
    use crate::partir::actions::{Action, DecisionState};
    use crate::partir::mesh::{AxisId, Mesh};
    use crate::partir::program::PartirProgram;
    use crate::spmd::collectives::CollectiveStats;
    use crate::spmd::lower::lower;

    #[test]
    fn builds_and_verifies() {
        let m = build_graphnet(&GraphNetConfig::small());
        verify(&m.func).unwrap();
        assert_eq!(m.params.len(), 16);
    }

    #[test]
    fn edge_sharding_lowPers_comm_vs_gather_storm() {
        // Input-edge sharding: tile edges + senders + receivers on dim 0.
        let m = build_graphnet(&GraphNetConfig::small());
        let p = PartirProgram::new(m.func.clone(), Mesh::new(&[("shard", 4)]));
        let ax = AxisId(0);
        let st = DecisionState {
            actions: vec![
                Action::Tile { v: m.edges_arg, dim: 0, axis: ax },
                Action::Tile { v: crate::ir::ValueId(2), dim: 0, axis: ax }, // senders
                Action::Tile { v: crate::ir::ValueId(3), dim: 0, axis: ax }, // receivers
            ],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let sp = lower(&p.func, &p.mesh, &p.prop, &dm);
        let s = CollectiveStats::from_collectives(&sp.collectives);
        // segment-sum over sharded edges -> all-reduce per round (+ bwd),
        // but no all-gathers of node features.
        assert!(s.all_reduce_count >= 2, "{s:?}");
        // Edge tensors tiled => per-device memory shrinks.
        use crate::cost::liveness::peak_memory;
        let dm0 = crate::partir::dist::DistMap::new(&p.func, &p.mesh);
        let m0 = peak_memory(&p.func, &p.mesh, &dm0);
        let m1 = peak_memory(&p.func, &p.mesh, &dm);
        assert!(m1.peak_bytes < m0.peak_bytes);
    }
}
