//! Analytical device simulation: accelerator models (TPU v2/v3) and the
//! roofline + α-β runtime estimator used to reproduce Figure 7.

pub mod device;
pub mod exec;

pub use device::Device;
pub use exec::{estimate, RuntimeEstimate};
