//! Analytical accelerator model. The paper evaluates on TPU v3 (16 GB
//! HBM per device, §3); with no TPUs available here, Figure 7's runtimes
//! are reproduced with a roofline + α-β model over the same lowered SPMD
//! programs (DESIGN.md §3 — the figure's claim is *relative*:
//! near-Megatron ≈ Megatron, which an analytical model preserves).

/// Device characteristics.
#[derive(Debug, Clone)]
pub struct Device {
    pub name: &'static str,
    /// Peak matmul FLOP/s (MXU).
    pub flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Inter-chip interconnect (ICI) link bandwidth, bytes/s.
    pub ici_bw: f64,
    /// Per-hop collective latency, seconds.
    pub alpha: f64,
    /// HBM capacity, bytes.
    pub hbm_bytes: i64,
}

impl Device {
    /// TPU v3 core: 16 GB HBM (paper §3), ~52.5 TFLOP/s bf16 MXU peak
    /// (420 TFLOP/s per 4-chip board / 8 cores), ~450 GB/s HBM per core,
    /// ~70 GB/s ICI link.
    pub fn tpu_v3() -> Device {
        Device {
            name: "TPUv3",
            flops: 52.5e12,
            hbm_bw: 450e9,
            ici_bw: 70e9,
            alpha: 1e-6,
            hbm_bytes: 16 * (1 << 30),
        }
    }

    /// A smaller device for memory-pressure experiments ("partitioning
    /// models to fit onto older accelerators with less memory", §1).
    pub fn tpu_v2() -> Device {
        Device {
            name: "TPUv2",
            flops: 22.5e12,
            hbm_bw: 300e9,
            ici_bw: 50e9,
            alpha: 1.5e-6,
            hbm_bytes: 8 * (1 << 30),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tpu_v3_matches_paper_memory() {
        let d = Device::tpu_v3();
        assert_eq!(d.hbm_bytes, 17_179_869_184); // 16 GiB
        assert!(d.flops > 1e13);
    }
}
