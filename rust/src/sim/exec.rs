//! Roofline execution-time estimator for lowered SPMD programs: each op
//! costs `max(flops/peak_flops, bytes/hbm_bw)`, collectives cost their
//! α-β ring time, and the device-local program is assumed serialised
//! (conservative, like the paper's compiler-internal cost models that
//! "estimate peak memory, runtime, and communication", §2).

use super::device::Device;
use crate::ir::{Func, OpKind};
use crate::partir::dist::DistMap;
use crate::partir::mesh::Mesh;
use crate::partir::propagate::Propagator;
use crate::spmd::collectives::collective_seconds;
use crate::spmd::lower::SpmdProgram;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct RuntimeEstimate {
    pub compute_seconds: f64,
    pub memory_seconds: f64,
    /// max(compute, memory) accumulated per op.
    pub op_seconds: f64,
    pub collective_seconds: f64,
    pub total_flops: f64,
}

impl RuntimeEstimate {
    pub fn total_seconds(&self) -> f64 {
        self.op_seconds + self.collective_seconds
    }

    /// Fold one node's roofline term in — the single accumulation
    /// definition the full pass ([`estimate`]) and the cost ledger's
    /// re-aggregation share, so both perform the identical sequence of
    /// additions per accumulator.
    #[inline]
    pub fn add_node_term(&mut self, t: &NodeTerm) {
        self.compute_seconds += t.compute_seconds;
        self.memory_seconds += t.memory_seconds;
        self.op_seconds += t.compute_seconds.max(t.memory_seconds);
        self.total_flops += t.flops;
    }
}

/// Per-device FLOPs of one node under distribution `dm`.
pub fn node_flops(f: &Func, mesh: &Mesh, dm: &DistMap, ni: usize) -> f64 {
    let node = &f.nodes[ni];
    let out_v = f.num_args() + ni;
    let local_out: f64 = dm.local_dims(out_v, &node.ty.dims, mesh).iter().product::<i64>() as f64;
    match &node.op {
        OpKind::Dot(d) => {
            // 2 * output elements * contracted extent (local on lhs).
            let lhs = node.inputs[0].index();
            let lhs_dims = dm.local_dims(lhs, &f.value_type(node.inputs[0]).dims, mesh);
            let k: f64 = d.lhs_contract.iter().map(|&c| lhs_dims[c] as f64).product();
            2.0 * local_out * k
        }
        OpKind::Reduce { .. } => {
            let inp = node.inputs[0].index();
            dm.local_dims(inp, &f.value_type(node.inputs[0]).dims, mesh)
                .iter()
                .product::<i64>() as f64
        }
        op => local_out * op.flops_per_output(),
    }
}

/// Per-device HBM traffic of one node (read operands + write result).
pub fn node_bytes(f: &Func, mesh: &Mesh, dm: &DistMap, ni: usize) -> f64 {
    let node = &f.nodes[ni];
    let out_v = f.num_args() + ni;
    let mut b = dm.local_bytes(out_v, node.ty.byte_size(), mesh) as f64;
    for &inp in &node.inputs {
        b += dm.local_bytes(inp.index(), f.value_type(inp).byte_size(), mesh) as f64;
    }
    b
}

/// One node's contribution to the roofline estimate: the per-node term
/// the cost ledger caches. A term is a pure function of the node's
/// operand/result distribution rows (plus the immutable program tables),
/// so a cached term is bit-identical to a freshly computed one whenever
/// those rows are unchanged.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NodeTerm {
    pub compute_seconds: f64,
    pub memory_seconds: f64,
    pub flops: f64,
}

/// Compute node `ni`'s roofline term under `dm` — exactly the per-node
/// body of [`estimate`], factored out so the ledger and the full pass
/// share one definition (EXPERIMENTS.md §Perf opt 2: local element
/// counts come from the Propagator's precomputed global tables divided
/// by the tiled axis sizes, no local dim vectors materialised).
pub fn node_term(
    f: &Func,
    mesh: &Mesh,
    prop: &Propagator,
    dm: &DistMap,
    dev: &Device,
    ni: usize,
) -> NodeTerm {
    let num_args = f.num_args();
    // local element count without allocating
    let local_elems = |v: usize| -> f64 {
        let mut e = prop.global_elems[v] as f64;
        for a in 0..dm.num_axes {
            if dm.d[v][a] != crate::partir::dist::UNKNOWN {
                e /= mesh.size(crate::partir::mesh::AxisId(a)) as f64;
            }
        }
        e
    };
    let local_bytes_of = |v: usize| -> f64 { dm.local_bytes(v, prop.global_bytes[v], mesh) as f64 };
    let node = &f.nodes[ni];
    let out_v = num_args + ni;
    let fl = match &node.op {
        OpKind::Dot(d) => {
            let lhs = node.inputs[0].index();
            let mut k = 1f64;
            for &c in &d.lhs_contract {
                let mut extent = prop.dims_of(lhs)[c] as f64;
                for a in 0..dm.num_axes {
                    if dm.d[lhs][a] == c as u8 {
                        extent /= mesh.size(crate::partir::mesh::AxisId(a)) as f64;
                    }
                }
                k *= extent;
            }
            2.0 * local_elems(out_v) * k
        }
        OpKind::Reduce { .. } => local_elems(node.inputs[0].index()),
        op => local_elems(out_v) * op.flops_per_output(),
    };
    let mut by = local_bytes_of(out_v);
    for &inp in &node.inputs {
        by += local_bytes_of(inp.index());
    }
    NodeTerm { compute_seconds: fl / dev.flops, memory_seconds: by / dev.hbm_bw, flops: fl }
}

/// Estimate the per-step runtime of a lowered SPMD program.
///
/// Accumulation order (ascending node index, collectives in emission
/// order) is part of the contract: the cost ledger re-aggregates cached
/// [`NodeTerm`]s in this exact order, which is what makes its float
/// sums bit-identical to this full pass.
pub fn estimate(p: &SpmdProgram, dev: &Device) -> RuntimeEstimate {
    let mut est = RuntimeEstimate::default();
    for ni in 0..p.func.num_nodes() {
        let t = node_term(p.func, p.mesh, p.prop, p.dm, dev, ni);
        est.add_node_term(&t);
    }
    for c in &p.collectives {
        est.collective_seconds += collective_seconds(c, p.mesh, dev.ici_bw, dev.alpha);
    }
    est
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArgKind, GraphBuilder, TensorType, ValueId};
    use crate::partir::actions::{Action, DecisionState};
    use crate::partir::mesh::AxisId;
    use crate::partir::program::PartirProgram;
    use crate::spmd::lower::lower;

    fn matmul_prog(mesh: Mesh) -> PartirProgram {
        let mut b = GraphBuilder::new("mm");
        let x = b.arg("x", TensorType::f32(&[512, 512]), ArgKind::Input);
        let w = b.arg("w", TensorType::f32(&[512, 512]), ArgKind::Parameter);
        let y = b.matmul(x, w);
        b.output(y);
        PartirProgram::new(b.finish(), mesh)
    }

    #[test]
    fn flops_match_matmul() {
        let p = matmul_prog(Mesh::new(&[("s", 1)]));
        let dm = DistMap::new(&p.func, &p.mesh);
        assert_eq!(node_flops(&p.func, &p.mesh, &dm, 0), 2.0 * 512.0 * 512.0 * 512.0);
    }

    #[test]
    fn sharding_divides_flops_and_adds_no_comm_for_colsplit() {
        let p = matmul_prog(Mesh::new(&[("model", 4)]));
        let st = DecisionState {
            actions: vec![Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) }],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        assert_eq!(node_flops(&p.func, &p.mesh, &dm, 0), 2.0 * 512.0 * 512.0 * 512.0 / 4.0);
        let sp = lower(&p.func, &p.mesh, &p.prop, &dm);
        let est = estimate(&sp, &Device::tpu_v3());
        assert_eq!(est.collective_seconds, 0.0);
        assert!(est.total_seconds() > 0.0);
    }

    #[test]
    fn partial_sum_pays_all_reduce_time() {
        let p = matmul_prog(Mesh::new(&[("model", 4)]));
        let st = DecisionState {
            actions: vec![
                Action::Tile { v: ValueId(0), dim: 1, axis: AxisId(0) },
                Action::Tile { v: ValueId(1), dim: 0, axis: AxisId(0) },
            ],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let sp = lower(&p.func, &p.mesh, &p.prop, &dm);
        let est = estimate(&sp, &Device::tpu_v3());
        assert!(est.collective_seconds > 0.0);
    }

    #[test]
    fn sharded_runtime_beats_replicated() {
        let p = matmul_prog(Mesh::new(&[("model", 4)]));
        let dm0 = DistMap::new(&p.func, &p.mesh);
        let sp0 = lower(&p.func, &p.mesh, &p.prop, &dm0);
        let t0 = estimate(&sp0, &Device::tpu_v3()).total_seconds();

        let st = DecisionState {
            actions: vec![Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) }],
            atomic: Default::default(),
        };
        let (dm, _) = p.apply(&st);
        let sp = lower(&p.func, &p.mesh, &p.prop, &dm);
        let t1 = estimate(&sp, &Device::tpu_v3()).total_seconds();
        assert!(t1 < t0, "sharded {t1} should beat replicated {t0}");
    }
}
