//! Flight recorder: lock-striped, allocation-free span/event tracing.
//!
//! The recorder is a pure side channel (DESIGN.md §12): it never feeds back
//! into search or evaluation state, so enabling it must leave plan JSON
//! byte-identical for a fixed (seed, K). The hot-path contract is:
//!
//! - **Disabled path is one atomic load.** Every recording entry point starts
//!   with `enabled()` — a `Relaxed` load of a single `AtomicBool` — and
//!   returns immediately when tracing is off.
//! - **No allocation or formatting while recording.** Events store
//!   `&'static str` names/categories, integer nanosecond timestamps, and up
//!   to two `(&'static str, i64)` args. Rings are pre-sized at thread
//!   registration (`RING_CAPACITY` events); once full they overwrite the
//!   oldest entries and count drops. JSON is only produced at export time.
//! - **Lock striping.** Each thread owns its own ring behind its own mutex;
//!   the global registry mutex is touched only at thread registration and
//!   export, never per event.
//!
//! Export produces Chrome trace-event JSON (`chrome_trace()`) loadable in
//! Perfetto, or one JSON object per line (`jsonl()`). RAII `SpanGuard`s push
//! a `Begin` event at construction and an `End` at drop, so per-ring order
//! is already a correct nesting order; export sanitizes the tail cases
//! (ring-evicted begins, unclosed spans at export time).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Events retained per thread before the ring starts overwriting.
pub const RING_CAPACITY: usize = 1 << 16;

/// Maximum number of inline integer args per event.
pub const MAX_ARGS: usize = 2;

/// Virtual pid for wall-clock events (service, executor, ledger).
pub const PID_WALL: u64 = 1;
/// Virtual pid for simulated-time events (pipeline schedule slices).
pub const PID_SIM: u64 = 2;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Span open (Chrome `ph:"B"`).
    Begin,
    /// Span close (Chrome `ph:"E"`).
    End,
    /// Point event (Chrome `ph:"i"`).
    Instant,
    /// Span recorded in one shot at its end with an explicit start time
    /// (exported as an adjacent `B`/`E` pair).
    Complete { start_ns: u64 },
    /// Simulated-schedule interval: exported as `ph:"X"` on [`PID_SIM`]
    /// with `tid = stage`, timestamps taken from the simulated clock.
    Slice { stage: u32 },
}

#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub kind: EventKind,
    pub name: &'static str,
    pub cat: &'static str,
    /// Nanoseconds since the recorder epoch (simulated ns for slices).
    pub ts_ns: u64,
    /// For `Slice`: duration in simulated ns. Unused otherwise.
    pub dur_ns: u64,
    /// Request correlation id (0 = none).
    pub req: u64,
    pub args: [(&'static str, i64); MAX_ARGS],
    pub num_args: u8,
}

/// Fixed-capacity overwrite-oldest event buffer. Pre-sized at registration;
/// `push` never allocates.
struct Ring {
    buf: Vec<Event>,
    /// Index of the slot the next push writes (wraps once full).
    head: usize,
    /// Total events ever pushed; `min(pushed, capacity)` are retained.
    pushed: u64,
}

impl Ring {
    fn new() -> Self {
        Ring {
            buf: Vec::with_capacity(RING_CAPACITY),
            head: 0,
            pushed: 0,
        }
    }

    fn push(&mut self, ev: Event) {
        if self.buf.len() < RING_CAPACITY {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
        }
        self.head = (self.head + 1) % RING_CAPACITY;
        self.pushed += 1;
    }

    fn dropped(&self) -> u64 {
        self.pushed - self.buf.len() as u64
    }

    /// Events in push order (oldest first).
    fn ordered(&self) -> Vec<Event> {
        if self.buf.len() < RING_CAPACITY {
            self.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.buf.len());
            out.extend_from_slice(&self.buf[self.head..]);
            out.extend_from_slice(&self.buf[..self.head]);
            out
        }
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.pushed = 0;
    }
}

/// One thread's stripe: a stable tid plus its own ring behind its own lock.
struct ThreadLog {
    tid: u64,
    ring: Mutex<Ring>,
}

pub struct Recorder {
    enabled: AtomicBool,
    epoch: Instant,
    next_tid: AtomicU64,
    next_req: AtomicU64,
    threads: Mutex<Vec<Arc<ThreadLog>>>,
}

thread_local! {
    static LOCAL: std::cell::RefCell<Option<Arc<ThreadLog>>> =
        const { std::cell::RefCell::new(None) };
}

static RECORDER: OnceLock<Recorder> = OnceLock::new();

/// The process-wide recorder (created lazily, disabled by default).
pub fn recorder() -> &'static Recorder {
    RECORDER.get_or_init(|| Recorder {
        enabled: AtomicBool::new(false),
        epoch: Instant::now(),
        next_tid: AtomicU64::new(1),
        next_req: AtomicU64::new(1),
        threads: Mutex::new(Vec::new()),
    })
}

impl Recorder {
    /// The one-atomic gate every recording entry point checks first.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    /// Drop all recorded events (rings stay registered and pre-sized).
    pub fn clear(&self) {
        let threads = self.threads.lock().unwrap();
        for t in threads.iter() {
            t.ring.lock().unwrap().clear();
        }
    }

    /// Monotonic nanoseconds since the recorder epoch.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Fresh request correlation id (never 0).
    pub fn new_request_id(&self) -> u64 {
        self.next_req.fetch_add(1, Ordering::Relaxed)
    }

    /// This thread's stripe, registering it on first use.
    fn local(&'static self) -> Arc<ThreadLog> {
        LOCAL.with(|slot| {
            let mut slot = slot.borrow_mut();
            if let Some(log) = slot.as_ref() {
                return Arc::clone(log);
            }
            let log = Arc::new(ThreadLog {
                tid: self.next_tid.fetch_add(1, Ordering::Relaxed),
                ring: Mutex::new(Ring::new()),
            });
            self.threads.lock().unwrap().push(Arc::clone(&log));
            *slot = Some(Arc::clone(&log));
            log
        })
    }

    #[inline]
    fn push(&'static self, ev: Event) {
        let log = self.local();
        log.ring.lock().unwrap().push(ev);
    }

    /// Open a span; the returned guard records the matching end on drop.
    /// When tracing is disabled this is a single atomic load.
    #[inline]
    pub fn span(&'static self, name: &'static str, cat: &'static str, req: u64) -> SpanGuard {
        self.span_with_args(name, cat, req, &[])
    }

    #[inline]
    pub fn span_with_args(
        &'static self,
        name: &'static str,
        cat: &'static str,
        req: u64,
        args: &[(&'static str, i64)],
    ) -> SpanGuard {
        if !self.enabled() {
            return SpanGuard { rec: None, name, cat, req };
        }
        self.push(make_event(EventKind::Begin, name, cat, self.now_ns(), 0, req, args));
        SpanGuard { rec: Some(self), name, cat, req }
    }

    /// Point event.
    #[inline]
    pub fn instant(
        &'static self,
        name: &'static str,
        cat: &'static str,
        req: u64,
        args: &[(&'static str, i64)],
    ) {
        if !self.enabled() {
            return;
        }
        self.push(make_event(EventKind::Instant, name, cat, self.now_ns(), 0, req, args));
    }

    /// Record a whole span in one shot, with a start time captured earlier
    /// via [`Recorder::now_ns`]. Used where the span's args are only known
    /// at the end (e.g. ledger refresh reuse counts).
    #[inline]
    pub fn complete(
        &'static self,
        name: &'static str,
        cat: &'static str,
        req: u64,
        start_ns: u64,
        args: &[(&'static str, i64)],
    ) {
        if !self.enabled() {
            return;
        }
        let end = self.now_ns().max(start_ns);
        self.push(make_event(EventKind::Complete { start_ns }, name, cat, end, 0, req, args));
    }

    /// Simulated-schedule interval (pipeline stage busy time). Timestamps
    /// are simulated nanoseconds, rendered on their own virtual process.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn slice(
        &'static self,
        name: &'static str,
        cat: &'static str,
        req: u64,
        stage: u32,
        start_ns: u64,
        dur_ns: u64,
        args: &[(&'static str, i64)],
    ) {
        if !self.enabled() {
            return;
        }
        self.push(make_event(EventKind::Slice { stage }, name, cat, start_ns, dur_ns, req, args));
    }

    /// Total events evicted from full rings since the last clear.
    pub fn dropped_events(&self) -> u64 {
        let threads = self.threads.lock().unwrap();
        threads.iter().map(|t| t.ring.lock().unwrap().dropped()).sum()
    }

    /// Flat export tokens: `(pid, tid, seq, event)` sorted for rendering.
    /// `seq` preserves per-ring push order so B/E nesting survives equal
    /// timestamps; orphan `End`s (their `Begin` was ring-evicted) are
    /// dropped and unclosed `Begin`s get a synthetic end at the max
    /// timestamp seen.
    fn export_tokens(&self) -> Vec<(u64, u64, u64, Event)> {
        let threads = self.threads.lock().unwrap();
        let mut tokens: Vec<(u64, u64, u64, Event)> = Vec::new();
        let mut max_ts = 0u64;
        for t in threads.iter() {
            let events = t.ring.lock().unwrap().ordered();
            // Sanitize per ring: drop End events whose Begin was evicted.
            let mut depth: i64 = 0;
            let mut kept: Vec<Event> = Vec::with_capacity(events.len());
            for ev in events {
                match ev.kind {
                    EventKind::Begin => {
                        depth += 1;
                        kept.push(ev);
                    }
                    EventKind::End => {
                        if depth > 0 {
                            depth -= 1;
                            kept.push(ev);
                        }
                    }
                    _ => kept.push(ev),
                }
                max_ts = max_ts.max(ev.ts_ns.saturating_add(ev.dur_ns));
            }
            for (seq, ev) in kept.into_iter().enumerate() {
                let pid = match ev.kind {
                    EventKind::Slice { .. } => PID_SIM,
                    _ => PID_WALL,
                };
                let tid = match ev.kind {
                    EventKind::Slice { stage } => stage as u64,
                    _ => t.tid,
                };
                tokens.push((pid, tid, seq as u64, ev));
            }
        }
        // Synthesize ends for spans still open at export (per wall tid).
        let mut open: BTreeMap<u64, Vec<Event>> = BTreeMap::new();
        for (pid, tid, _, ev) in &tokens {
            if *pid != PID_WALL {
                continue;
            }
            let stack = open.entry(*tid).or_default();
            match ev.kind {
                EventKind::Begin => stack.push(*ev),
                EventKind::End => {
                    stack.pop();
                }
                _ => {}
            }
        }
        for (tid, stack) in open {
            let base = tokens
                .iter()
                .filter(|(p, t, _, _)| *p == PID_WALL && *t == tid)
                .map(|(_, _, s, _)| *s)
                .max()
                .unwrap_or(0);
            for (i, b) in stack.into_iter().rev().enumerate() {
                let mut end = b;
                end.kind = EventKind::End;
                end.ts_ns = max_ts;
                end.num_args = 0;
                tokens.push((PID_WALL, tid, base + 1 + i as u64, end));
            }
        }
        tokens.sort_by(|a, b| {
            let ka = (a.0, a.1, a.3.ts_ns, a.2);
            let kb = (b.0, b.1, b.3.ts_ns, b.2);
            ka.cmp(&kb)
        });
        tokens
    }

    /// Chrome trace-event JSON (the object form Perfetto accepts):
    /// `{"traceEvents": [...], "displayTimeUnit": "ns"}`.
    pub fn chrome_trace(&self) -> Json {
        let events: Vec<Json> = self.export_tokens().into_iter().map(token_json).collect();
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ns")),
            ("droppedEvents", Json::num(self.dropped_events() as f64)),
        ])
    }

    /// One Chrome trace-event object per line.
    pub fn jsonl(&self) -> String {
        let mut out = String::new();
        for tok in self.export_tokens() {
            out.push_str(&token_json(tok).to_string());
            out.push('\n');
        }
        out
    }
}

#[inline]
fn make_event(
    kind: EventKind,
    name: &'static str,
    cat: &'static str,
    ts_ns: u64,
    dur_ns: u64,
    req: u64,
    args: &[(&'static str, i64)],
) -> Event {
    let mut ev = Event {
        kind,
        name,
        cat,
        ts_ns,
        dur_ns,
        req,
        args: [("", 0); MAX_ARGS],
        num_args: args.len().min(MAX_ARGS) as u8,
    };
    for (i, &a) in args.iter().take(MAX_ARGS).enumerate() {
        ev.args[i] = a;
    }
    ev
}

fn args_json(ev: &Event) -> Json {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    if ev.req != 0 {
        fields.push(("req", Json::num(ev.req as f64)));
    }
    for &(k, v) in ev.args.iter().take(ev.num_args as usize) {
        fields.push((k, Json::num(v as f64)));
    }
    Json::obj(fields)
}

fn token_json((pid, tid, _seq, ev): (u64, u64, u64, Event)) -> Json {
    let ph = match ev.kind {
        EventKind::Begin => "B",
        EventKind::End => "E",
        EventKind::Instant => "i",
        EventKind::Complete { .. } => "X",
        EventKind::Slice { .. } => "X",
    };
    let ts_us = match ev.kind {
        EventKind::Complete { start_ns } => start_ns as f64 / 1000.0,
        _ => ev.ts_ns as f64 / 1000.0,
    };
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", Json::str(ev.name)),
        ("cat", Json::str(ev.cat)),
        ("ph", Json::str(ph)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("ts", Json::Num(ts_us)),
    ];
    match ev.kind {
        EventKind::Complete { start_ns } => {
            fields.push(("dur", Json::Num((ev.ts_ns - start_ns) as f64 / 1000.0)));
        }
        EventKind::Slice { .. } => {
            fields.push(("dur", Json::Num(ev.dur_ns as f64 / 1000.0)));
        }
        EventKind::Instant => {
            fields.push(("s", Json::str("t")));
        }
        _ => {}
    }
    fields.push(("args", args_json(&ev)));
    Json::obj(fields)
}

/// RAII span: records `Begin` at creation (via [`Recorder::span`]) and `End`
/// at drop. Cheap no-op when tracing was disabled at creation.
pub struct SpanGuard {
    rec: Option<&'static Recorder>,
    name: &'static str,
    cat: &'static str,
    req: u64,
}

impl SpanGuard {
    /// Attach up to [`MAX_ARGS`] integer args to the closing `End` event.
    pub fn end_with_args(mut self, args: &[(&'static str, i64)]) {
        if let Some(rec) = self.rec.take() {
            let now = rec.now_ns();
            rec.push(make_event(EventKind::End, self.name, self.cat, now, 0, self.req, args));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(rec) = self.rec.take() {
            let now = rec.now_ns();
            rec.push(make_event(EventKind::End, self.name, self.cat, now, 0, self.req, &[]));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut ring = Ring::new();
        for i in 0..(RING_CAPACITY as u64 + 10) {
            ring.push(make_event(EventKind::Instant, "e", "t", i, 0, 0, &[]));
        }
        assert_eq!(ring.dropped(), 10);
        let events = ring.ordered();
        assert_eq!(events.len(), RING_CAPACITY);
        assert_eq!(events[0].ts_ns, 10);
        assert_eq!(events.last().unwrap().ts_ns, RING_CAPACITY as u64 + 9);
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = recorder();
        rec.disable();
        rec.clear();
        {
            let _g = rec.span("noop", "test", 0);
            rec.instant("noop", "test", 0, &[]);
        }
        let trace = rec.chrome_trace();
        let events = trace.get("traceEvents").and_then(|j| j.as_arr()).unwrap();
        assert!(events.is_empty());
    }
}
