//! Observability: flight-recorder tracing, a metrics registry, and search
//! telemetry (DESIGN.md §12).
//!
//! Everything here is a pure side channel over the deterministic pipeline:
//! enabling or disabling any of it leaves plan JSON byte-identical for a
//! fixed (seed, K) — pinned by `tests/obs_determinism.rs`.

pub mod explain;
pub mod metrics;
pub mod recorder;
pub mod telemetry;

pub use explain::{explain_degradation, explain_plan};
pub use metrics::{metrics, register_service_metrics, Counter, Gauge, Histogram, HistogramSnapshot};
pub use recorder::{recorder, EventKind, Recorder, SpanGuard};
pub use telemetry::{telemetry, RequestTelemetry, RoundSample, TelemetryHub};

use crate::util::json::Json;

/// Combined metrics snapshot for `--metrics-out`: the registry (counters,
/// gauges, histograms with p50/p90/p99) plus the per-request telemetry
/// timelines retained by the hub.
pub fn metrics_snapshot() -> Json {
    let registry = metrics().snapshot();
    let mut fields: Vec<(&str, Json)> = Vec::new();
    for key in ["counters", "gauges", "histograms"] {
        if let Some(section) = registry.get(key) {
            fields.push((key, section.clone()));
        }
    }
    fields.push(("requests", telemetry().to_json()));
    Json::obj(fields)
}
