//! `explain`: replay a partition plan's decision trace as a human-readable
//! tactic/decision timeline (the CLI front-end for the PartIR-style
//! trace-of-tactics abstraction — see PAPER.md and DESIGN.md §12).

use crate::session::plan::PartitionPlan;
use crate::util::json::Json;
use crate::util::stats::{fmt_bytes, fmt_secs};

/// Render the degradation annotations a plan-service response wrapper
/// may carry (DESIGN.md §14) — the `degraded` marker, the `fallback`
/// flag, and worker-panic counts from the search stats — as a block of
/// `!` lines to print above the plan narrative. `None` when the
/// response is a full-quality plan (so healthy output is unchanged).
pub fn explain_degradation(doc: &Json) -> Option<String> {
    let mut out = String::new();
    if let Some(kind) = doc.get("degraded").and_then(Json::as_str) {
        out.push_str(&match kind {
            "deadline" => "! degraded: deadline hit — best-so-far anytime plan, not cached\n"
                .to_string(),
            "panic" => "! degraded: all search workers panicked — salvaged plan, not cached\n"
                .to_string(),
            "shed" => "! degraded: shed at admission — answered without a fresh search\n"
                .to_string(),
            other => format!("! degraded: {other}\n"),
        });
    }
    if doc.get("fallback").and_then(Json::as_bool).unwrap_or(false) {
        out.push_str("! fallback: zero-search plan (pre-tactics + InferRest only)\n");
    }
    let panics = doc
        .get("search")
        .and_then(|s| s.get("worker_panics"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    if panics > 0.0 {
        out.push_str(&format!(
            "! {} search worker{} panicked; surviving workers produced this plan\n",
            panics as u64,
            if panics as u64 == 1 { "" } else { "s" },
        ));
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Render a plan (typically loaded back from the cache or a `partition`
/// JSON dump) into an indented decision timeline with a cost summary.
pub fn explain_plan(plan: &PartitionPlan) -> String {
    let mut out = String::new();
    let mesh: Vec<String> =
        plan.mesh_axes.iter().map(|(name, size)| format!("{name}={size}")).collect();
    out.push_str(&format!("plan over mesh [{}]\n", mesh.join(", ")));
    out.push_str(&format!(
        "  cost {:.3e}  ({} compute, {} collectives, peak {} {})\n",
        plan.eval.cost,
        fmt_secs(plan.eval.runtime.compute_seconds),
        fmt_secs(plan.eval.runtime.collective_seconds),
        fmt_bytes(plan.eval.memory.peak_bytes as f64),
        if plan.eval.fits_memory { "fits" } else { "OVER BUDGET" },
    ));
    out.push_str(&format!(
        "  {} decisions over {} targets ({} worklist), best at episode {}\n",
        plan.decisions, plan.targets, plan.worklist_size, plan.episodes_to_best,
    ));
    if let Some(pe) = &plan.eval.pipeline {
        out.push_str(&format!(
            "  pipeline: {} stages x {} microbatches, cuts {:?}, bubble {:.1}%, makespan {}\n",
            pe.stages,
            pe.microbatches,
            pe.cuts,
            pe.bubble_fraction * 100.0,
            fmt_secs(pe.makespan_seconds),
        ));
    }

    out.push_str("\nsharding:\n");
    for (label, specs) in [("in ", &plan.input_specs), ("out", &plan.output_specs)] {
        for spec in specs.iter() {
            let desc = if spec.replicated() {
                "replicated".to_string()
            } else {
                spec.tilings
                    .iter()
                    .map(|(axis, dim)| format!("dim{dim}@{axis}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            };
            out.push_str(&format!("  {label} {:<12} {desc}\n", spec.name));
        }
    }

    out.push_str("\ntimeline:\n");
    let mut last_phase = "";
    for (i, line) in plan.trace.iter().enumerate() {
        let (phase, detail) = match line.split_once(':') {
            Some((p, d)) => (p.trim(), d.trim()),
            None => ("", line.as_str()),
        };
        if phase != last_phase {
            out.push_str(&format!("  [{phase}]\n"));
            last_phase = phase;
        }
        out.push_str(&format!("    {i:>3}. {detail}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::composite::Evaluation;
    use crate::session::plan::ShardSpec;

    #[test]
    fn explain_degradation_renders_response_annotations() {
        let healthy = crate::util::json::parse(r#"{"id":"a","cached":true}"#).unwrap();
        assert_eq!(explain_degradation(&healthy), None, "healthy responses add nothing");
        let degraded = crate::util::json::parse(
            r#"{"id":"b","degraded":"deadline","fallback":true,"search":{"worker_panics":2}}"#,
        )
        .unwrap();
        let text = explain_degradation(&degraded).unwrap();
        assert!(text.contains("deadline hit"));
        assert!(text.contains("fallback"));
        assert!(text.contains("2 search workers panicked"));
        let shed = crate::util::json::parse(r#"{"degraded":"shed"}"#).unwrap();
        assert!(explain_degradation(&shed).unwrap().contains("shed at admission"));
    }

    #[test]
    fn explain_groups_trace_by_phase() {
        let json = sample_plan().to_json();
        let plan = PartitionPlan::from_json(&json).unwrap();
        let text = explain_plan(&plan);
        assert!(text.contains("plan over mesh [model=4]"));
        assert!(text.contains("[manual]"));
        assert!(text.contains("[search]"));
        assert!(text.contains("tile w dim 1"));
        assert!(text.contains("dim1@model"));
    }

    fn sample_plan() -> PartitionPlan {
        PartitionPlan {
            mesh_axes: vec![("model".to_string(), 4)],
            input_specs: vec![
                ShardSpec { name: "x".to_string(), tilings: vec![] },
                ShardSpec { name: "w".to_string(), tilings: vec![("model".to_string(), 1)] },
            ],
            output_specs: vec![ShardSpec { name: "y".to_string(), tilings: vec![] }],
            eval: Evaluation {
                memory: Default::default(),
                runtime: Default::default(),
                collectives: Default::default(),
                fits_memory: true,
                cost: 1.0,
                pipeline: None,
            },
            decisions: 1,
            episodes_to_best: 3,
            worklist_size: 2,
            targets: 2,
            wall_seconds: 0.0,
            trace: vec![
                "manual: shard x on batch".to_string(),
                "search: tile w dim 1 on model".to_string(),
                "search: keep y replicated".to_string(),
            ],
        }
    }
}
