//! Search telemetry: per-request reward curves, entropy timelines, steal
//! counts, and ledger reuse rates sampled at executor round barriers.
//!
//! Round samples are collected unconditionally — they are derived from
//! already-deterministic search state, the executor takes at most
//! `STEAL_ROUNDS` barriers per request, and sampling reads a handful of
//! counters — so the timeline is available to `ServeSummary`/`--metrics-out`
//! even when tracing is off, and cannot perturb determinism.

use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

/// Telemetry captured at one executor round barrier (across all workers).
#[derive(Clone, Debug)]
pub struct RoundSample {
    pub round: usize,
    /// Episodes completed so far (cumulative, all workers).
    pub episodes: usize,
    /// Best reward seen by any worker so far (f64::NEG_INFINITY if none).
    pub best_reward: f64,
    /// Mean root visit-count entropy across workers.
    pub mean_entropy: f64,
    /// Cumulative budget forfeitures up to this barrier.
    pub steals: usize,
    /// Ledger nodes_reused / (nodes_reused + nodes_recomputed) so far.
    pub ledger_reuse_rate: f64,
}

impl RoundSample {
    pub fn to_json(&self) -> Json {
        let best = if self.best_reward.is_finite() { self.best_reward } else { 0.0 };
        Json::obj(vec![
            ("round", Json::num(self.round as f64)),
            ("episodes", Json::num(self.episodes as f64)),
            ("best_reward", Json::Num(best)),
            ("entropy", Json::Num(self.mean_entropy)),
            ("steals", Json::num(self.steals as f64)),
            ("ledger_reuse_rate", Json::Num(self.ledger_reuse_rate)),
        ])
    }
}

/// One served request's telemetry, as retained by the hub.
#[derive(Clone, Debug)]
pub struct RequestTelemetry {
    pub id: String,
    pub fingerprint: u64,
    pub latency_ns: u64,
    pub cached: bool,
    pub dedup: bool,
    /// Empty for cache/dedup hits (no search ran for this request).
    pub samples: Vec<RoundSample>,
}

impl RequestTelemetry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("fingerprint", Json::str(format!("{:016x}", self.fingerprint))),
            ("cached", Json::Bool(self.cached)),
            ("dedup", Json::Bool(self.dedup)),
            ("latency_ms", Json::Num(self.latency_ns as f64 / 1e6)),
            ("timeline", Json::arr(self.samples.iter().map(|s| s.to_json()))),
        ])
    }
}

/// Retained per-request telemetry entries before the hub starts evicting
/// the oldest (bounds memory under sustained serve traffic).
pub const HUB_CAPACITY: usize = 256;

/// Process-wide bounded store of recent request telemetry, drained into
/// `--metrics-out` snapshots.
#[derive(Default)]
pub struct TelemetryHub {
    entries: Mutex<VecDeque<RequestTelemetry>>,
}

static HUB: OnceLock<TelemetryHub> = OnceLock::new();

pub fn telemetry() -> &'static TelemetryHub {
    HUB.get_or_init(TelemetryHub::default)
}

impl TelemetryHub {
    pub fn record(&self, entry: RequestTelemetry) {
        let mut entries = self.entries.lock().unwrap();
        if entries.len() == HUB_CAPACITY {
            entries.pop_front();
        }
        entries.push_back(entry);
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        self.entries.lock().unwrap().clear();
    }

    /// All retained entries, oldest first.
    pub fn to_json(&self) -> Json {
        let entries = self.entries.lock().unwrap();
        Json::arr(entries.iter().map(|e| e.to_json()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(i: usize) -> RequestTelemetry {
        RequestTelemetry {
            id: format!("r{i}"),
            fingerprint: i as u64,
            latency_ns: 1_000_000,
            cached: false,
            dedup: false,
            samples: Vec::new(),
        }
    }

    #[test]
    fn hub_evicts_oldest_beyond_capacity() {
        let hub = TelemetryHub::default();
        for i in 0..(HUB_CAPACITY + 3) {
            hub.record(entry(i));
        }
        assert_eq!(hub.len(), HUB_CAPACITY);
        let j = hub.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr[0].get("id").and_then(|v| v.as_str()), Some("r3"));
    }
}
