//! Process-wide metrics registry: counters, gauges, and log-bucketed
//! latency histograms with exact percentile extraction.
//!
//! Histograms bucket on powers of 2^(1/4) (four sub-buckets per octave), so
//! any reported percentile is a bucket lower bound within ~19% of the true
//! value, and values that are exact powers of two land on exact bucket
//! boundaries — which is what `tests/obs_determinism.rs` pins. All state is
//! atomic; recording never allocates or locks.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// Monotonic event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Signed instantaneous value (e.g. in-flight searches).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bucket 0 holds zeros; buckets `1 + 4e + s` hold values in
/// `[2^(e + s/4), 2^(e + (s+1)/4))` for exponent `e` in 0..64.
pub const NUM_BUCKETS: usize = 1 + 4 * 64;

// 2^(1/4), 2^(2/4), 2^(3/4): sub-bucket thresholds within one octave.
const C1: f64 = 1.189_207_115_002_721;
const C2: f64 = std::f64::consts::SQRT_2;
const C3: f64 = 1.681_792_830_507_429;

/// Index of the bucket containing `v`.
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        return 0;
    }
    let e = 63 - v.leading_zeros() as usize;
    let frac = v as f64 / (1u64 << e) as f64;
    let sub = if frac >= C3 {
        3
    } else if frac >= C2 {
        2
    } else if frac >= C1 {
        1
    } else {
        0
    };
    1 + 4 * e + sub
}

/// Lower bound of bucket `i` (0 for the zero bucket). Exact for integer
/// exponents of 2 since `powf` with an integral argument is exact there.
pub fn bucket_lower_bound(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        2f64.powf((i - 1) as f64 * 0.25)
    }
}

/// Lock-free log-bucketed histogram (base 2^(1/4)).
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: Vec<AtomicU64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count(),
            sum: self.sum(),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }

    /// Percentile straight off the live buckets (bucket lower bound).
    pub fn percentile(&self, q: f64) -> f64 {
        self.snapshot().percentile(q)
    }
}

/// Point-in-time copy of a histogram, diffable for run-scoped percentiles.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub counts: Vec<u64>,
}

impl HistogramSnapshot {
    /// Bucket-wise difference `self - earlier` (min/max are kept from
    /// `self`: they cannot be un-merged, and run-scoped callers only read
    /// percentiles off the diffed counts).
    pub fn delta(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count - earlier.count,
            sum: self.sum - earlier.sum,
            min: self.min,
            max: self.max,
            counts: self.counts.iter().zip(&earlier.counts).map(|(a, b)| a - b).collect(),
        }
    }

    /// Exact-rank percentile: the lower bound of the bucket holding the
    /// `max(1, ceil(q * count))`-th smallest recorded value. Returns 0.0
    /// for an empty histogram.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_lower_bound(i);
            }
        }
        bucket_lower_bound(NUM_BUCKETS - 1)
    }

    pub fn to_json(&self) -> Json {
        let count = self.count;
        let mean = if count == 0 { 0.0 } else { self.sum as f64 / count as f64 };
        Json::obj(vec![
            ("count", Json::num(count as f64)),
            ("sum", Json::num(self.sum as f64)),
            ("min", Json::num(if count == 0 { 0.0 } else { self.min as f64 })),
            ("max", Json::num(self.max as f64)),
            ("mean", Json::Num(mean)),
            ("p50", Json::Num(self.percentile(0.50))),
            ("p90", Json::Num(self.percentile(0.90))),
            ("p99", Json::Num(self.percentile(0.99))),
        ])
    }
}

/// Registry of named metrics. Names are `&'static str` so registration is
/// allocation-free; maps are sorted so snapshots have stable key order.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<&'static str, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<&'static str, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<&'static str, Arc<Histogram>>>,
}

static METRICS: OnceLock<Metrics> = OnceLock::new();

/// The process-wide registry.
pub fn metrics() -> &'static Metrics {
    METRICS.get_or_init(Metrics::default)
}

impl Metrics {
    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        let mut map = self.counters.lock().unwrap();
        Arc::clone(map.entry(name).or_default())
    }

    pub fn gauge(&self, name: &'static str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().unwrap();
        Arc::clone(map.entry(name).or_default())
    }

    pub fn histogram(&self, name: &'static str) -> Arc<Histogram> {
        let mut map = self.histograms.lock().unwrap();
        Arc::clone(map.entry(name).or_default())
    }

    /// JSON snapshot: `{"counters": {...}, "gauges": {...},
    /// "histograms": {name: {count, sum, min, max, mean, p50, p90, p99}}}`.
    pub fn snapshot(&self) -> Json {
        let counters = self.counters.lock().unwrap();
        let gauges = self.gauges.lock().unwrap();
        let histograms = self.histograms.lock().unwrap();
        let cj: Vec<(&str, Json)> =
            counters.iter().map(|(k, v)| (*k, Json::num(v.get() as f64))).collect();
        let gj: Vec<(&str, Json)> =
            gauges.iter().map(|(k, v)| (*k, Json::num(v.get() as f64))).collect();
        let hj: Vec<(&str, Json)> =
            histograms.iter().map(|(k, v)| (*k, v.snapshot().to_json())).collect();
        Json::obj(vec![
            ("counters", Json::obj(cj)),
            ("gauges", Json::obj(gj)),
            ("histograms", Json::obj(hj)),
        ])
    }
}

/// Canonical metric names. `configs/metrics_schema.json` mirrors these lists;
/// `python/check_metrics_schema.py` diffs serve snapshots against it, so new
/// names must land in both places.
pub mod names {
    pub const SERVICE_REQUESTS: &str = "service.requests";
    pub const SERVICE_ERRORS: &str = "service.errors";
    pub const SERVICE_CACHE_HITS: &str = "service.cache_hits";
    pub const SERVICE_CACHE_MISSES: &str = "service.cache_misses";
    pub const SERVICE_DEDUP_SERVED: &str = "service.dedup_served";
    pub const SERVICE_SEARCHES: &str = "service.searches";
    pub const SEARCH_EPISODES: &str = "search.episodes";
    pub const SEARCH_ROUNDS: &str = "search.rounds";
    pub const SEARCH_STEALS: &str = "search.steals";
    pub const EVAL_LOOKUPS: &str = "eval.lookups";
    pub const EVAL_MEMO_HITS: &str = "eval.memo_hits";
    pub const LEDGER_REFRESHES: &str = "ledger.refreshes";
    pub const LEDGER_NODES_REUSED: &str = "ledger.nodes_reused";
    pub const LEDGER_NODES_RECOMPUTED: &str = "ledger.nodes_recomputed";
    pub const PIPELINE_SEARCHES: &str = "pipeline.searches";
    pub const PERSIST_DISK_HITS: &str = "persist.disk_hits";
    pub const PERSIST_DISK_MISSES: &str = "persist.disk_misses";
    pub const PERSIST_APPENDS: &str = "persist.appends";
    pub const PERSIST_CORRUPT_RECORDS: &str = "persist.corrupt_records";
    pub const PERSIST_COMPACTIONS: &str = "persist.compactions";
    pub const PERSIST_QUARANTINED: &str = "persist.quarantined";
    pub const SERVICE_DEADLINE_HITS: &str = "service.deadline_hits";
    pub const SERVICE_SHED: &str = "service.shed";
    pub const SERVICE_FALLBACK_PLANS: &str = "service.fallback_plans";
    pub const SEARCH_WORKER_PANICS: &str = "search.worker_panics";
    pub const PERSIST_QUARANTINE_PRUNED: &str = "persist.quarantine_pruned";
    pub const SYNC_ROUNDS: &str = "sync.rounds";
    pub const SYNC_RECORDS_PULLED: &str = "sync.records_pulled";
    pub const SYNC_FRAMES_QUARANTINED: &str = "sync.frames_quarantined";
    pub const SYNC_CONFLICTS: &str = "sync.conflicts";
    pub const SYNC_PEER_SKEW: &str = "sync.peer_skew";
    pub const SYNC_RETRIES: &str = "sync.retries";
    pub const SYNC_PEERS_SKIPPED: &str = "sync.peers_skipped";
    pub const SERVICE_INFLIGHT_SEARCHES: &str = "service.inflight_searches";
    pub const SERVICE_QUEUE_DEPTH: &str = "service.queue_depth";
    pub const SERVICE_REQUEST_LATENCY_NS: &str = "service.request_latency_ns";
    pub const SEARCH_RUN_NS: &str = "search.run_ns";

    pub const ALL_COUNTERS: &[&str] = &[
        SERVICE_REQUESTS,
        SERVICE_ERRORS,
        SERVICE_CACHE_HITS,
        SERVICE_CACHE_MISSES,
        SERVICE_DEDUP_SERVED,
        SERVICE_SEARCHES,
        SEARCH_EPISODES,
        SEARCH_ROUNDS,
        SEARCH_STEALS,
        EVAL_LOOKUPS,
        EVAL_MEMO_HITS,
        LEDGER_REFRESHES,
        LEDGER_NODES_REUSED,
        LEDGER_NODES_RECOMPUTED,
        PIPELINE_SEARCHES,
        PERSIST_DISK_HITS,
        PERSIST_DISK_MISSES,
        PERSIST_APPENDS,
        PERSIST_CORRUPT_RECORDS,
        PERSIST_COMPACTIONS,
        PERSIST_QUARANTINED,
        SERVICE_DEADLINE_HITS,
        SERVICE_SHED,
        SERVICE_FALLBACK_PLANS,
        SEARCH_WORKER_PANICS,
        PERSIST_QUARANTINE_PRUNED,
        SYNC_ROUNDS,
        SYNC_RECORDS_PULLED,
        SYNC_FRAMES_QUARANTINED,
        SYNC_CONFLICTS,
        SYNC_PEER_SKEW,
        SYNC_RETRIES,
        SYNC_PEERS_SKIPPED,
    ];
    pub const ALL_GAUGES: &[&str] = &[SERVICE_INFLIGHT_SEARCHES, SERVICE_QUEUE_DEPTH];
    pub const ALL_HISTOGRAMS: &[&str] = &[SERVICE_REQUEST_LATENCY_NS, SEARCH_RUN_NS];
}

/// Pre-register every service metric so snapshot key sets are stable even
/// before the first request touches a given path.
pub fn register_service_metrics() {
    let m = metrics();
    for name in names::ALL_COUNTERS {
        m.counter(name);
    }
    for name in names::ALL_GAUGES {
        m.gauge(name);
    }
    for name in names::ALL_HISTOGRAMS {
        m.histogram(name);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_places_powers_of_two_on_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 5);
        assert_eq!(bucket_index(4), 9);
        assert_eq!(bucket_index(1024), 1 + 4 * 10);
        assert_eq!(bucket_lower_bound(bucket_index(1024)), 1024.0);
    }

    #[test]
    fn percentile_is_exact_on_power_of_two_inputs() {
        let h = Histogram::new();
        for v in [1u64, 2, 4, 8] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.percentile(0.50), 2.0);
        assert_eq!(s.percentile(0.90), 8.0);
        assert_eq!(s.percentile(0.99), 8.0);
        assert_eq!(s.count, 4);
        assert_eq!(s.sum, 15);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 8);
    }

    #[test]
    fn snapshot_delta_scopes_percentiles_to_a_run() {
        let h = Histogram::new();
        h.record(1_000_000);
        let before = h.snapshot();
        h.record(4);
        h.record(4);
        let after = h.snapshot();
        let run = after.delta(&before);
        assert_eq!(run.count, 2);
        assert_eq!(run.percentile(0.50), 4.0);
        assert_eq!(run.percentile(0.99), 4.0);
    }
}
