//! Experiment harness for the paper's figures: repeated search attempts
//! across a sweep of episode budgets, scored with the Megatron detector
//! (success rate → Fig 6/8/9) and the TPU-v3 runtime model (Fig 7).
//! Attempts run on std::threads (one fresh env per thread).

use super::env::{RewriteEnv, SearchOptions};
use super::mcts::{search, MctsConfig, SearchResult};
use crate::cost::composite::{CostWeights, Evaluation};
use crate::models::megatron::{check, MegatronVerdict};
use crate::models::transformer::TransformerModel;
use crate::partir::mesh::AxisId;
use crate::partir::program::PartirProgram;
use crate::sim::device::Device;
use crate::util::stats::{mean, rate};

/// One attempt's outcome.
#[derive(Clone)]
pub struct AttemptOutcome {
    pub result: SearchResult,
    pub verdict: MegatronVerdict,
    /// Simulated per-step runtime of the found solution (seconds).
    pub runtime_seconds: f64,
    /// Number of explicit decisions in the best solution.
    pub decisions: usize,
}

/// Aggregated row of a figure: one budget point.
#[derive(Clone, Debug)]
pub struct BudgetRow {
    pub budget: usize,
    pub success_rate: f64,
    pub near_rate: f64,
    pub mean_runtime: f64,
    pub megatron_runtime: f64,
    pub mean_decisions: f64,
}

/// Configuration of one figure experiment.
pub struct ExperimentConfig {
    pub budgets: Vec<usize>,
    pub attempts: usize,
    pub options: SearchOptions,
    pub mcts: MctsConfig,
    pub weights: CostWeights,
    pub seed: u64,
    pub threads: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            budgets: vec![100, 250, 500, 1000, 2000],
            attempts: 20,
            options: SearchOptions::default(),
            mcts: MctsConfig::default(),
            weights: CostWeights::default(),
            seed: 1234,
            threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
        }
    }
}

/// Pick a device that recreates the paper's memory pressure: Megatron
/// fits, full replication does not (26 GB model vs 16 GB TPU-v3).
pub fn pressured_device(reference: &Evaluation) -> Device {
    Device {
        hbm_bytes: (reference.memory.peak_bytes as f64 * 1.3) as i64,
        ..Device::tpu_v3()
    }
}

/// Run `attempts` independent searches at `budget` episodes each and
/// score them against the Megatron reference evaluation.
pub fn run_budget(
    program: &PartirProgram,
    reference: &Evaluation,
    device: &Device,
    cfg: &ExperimentConfig,
    budget: usize,
    worklist: &[crate::ir::ValueId],
) -> Vec<AttemptOutcome> {
    let threads = cfg.threads.max(1);
    let outcomes = std::sync::Mutex::new(Vec::with_capacity(cfg.attempts));
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(cfg.attempts) {
            scope.spawn(|| {
                let env = RewriteEnv::new(
                    program,
                    device.clone(),
                    cfg.weights.clone(),
                    cfg.options.clone(),
                    worklist,
                );
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= cfg.attempts {
                        break;
                    }
                    let seed = cfg
                        .seed
                        .wrapping_add((budget as u64) << 32)
                        .wrapping_add(i as u64 + 1);
                    let result = search(&env, budget, seed, cfg.mcts.clone());
                    let verdict = check(&result.best_eval, reference);
                    let outcome = AttemptOutcome {
                        runtime_seconds: result.best_eval.runtime.total_seconds(),
                        decisions: result
                            .best_state
                            .actions
                            .iter()
                            .filter(|a| matches!(a, crate::partir::actions::Action::Tile { .. }))
                            .count(),
                        result,
                        verdict,
                    };
                    outcomes.lock().unwrap().push(outcome);
                }
            });
        }
    });
    outcomes.into_inner().unwrap()
}

/// Full sweep over budgets → one row per budget.
pub fn run_sweep(
    program: &PartirProgram,
    model: &TransformerModel,
    axis: AxisId,
    cfg: &ExperimentConfig,
    worklist_override: Option<Vec<crate::ir::ValueId>>,
) -> (Vec<BudgetRow>, Evaluation) {
    // Reference on the pressured device.
    let probe = crate::models::megatron::reference_evaluation(
        program,
        model,
        axis,
        &Device::tpu_v3(),
        &cfg.weights,
    );
    let device = pressured_device(&probe);
    let reference = crate::models::megatron::reference_evaluation(
        program, model, axis, &device, &cfg.weights,
    );
    let worklist =
        worklist_override.unwrap_or_else(|| RewriteEnv::default_worklist(program));
    let mut rows = Vec::new();
    for &budget in &cfg.budgets {
        let outcomes = run_budget(program, &reference, &device, cfg, budget, &worklist);
        let runtimes: Vec<f64> = outcomes.iter().map(|o| o.runtime_seconds).collect();
        let decisions: Vec<f64> = outcomes.iter().map(|o| o.decisions as f64).collect();
        rows.push(BudgetRow {
            budget,
            success_rate: rate(&outcomes, |o| o.verdict.is_megatron),
            near_rate: rate(&outcomes, |o| o.verdict.is_megatron || o.verdict.near_megatron),
            mean_runtime: mean(&runtimes),
            megatron_runtime: reference.runtime.total_seconds(),
            mean_decisions: mean(&decisions),
        });
    }
    (rows, reference)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::transformer::{build_transformer, TransformerConfig};
    use crate::partir::mesh::Mesh;

    #[test]
    fn sweep_produces_monotonicish_success() {
        let model = build_transformer(&TransformerConfig::tiny(2));
        let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
        let cfg = ExperimentConfig {
            budgets: vec![20, 400],
            attempts: 6,
            ..Default::default()
        };
        let (rows, reference) = run_sweep(&program, &model, AxisId(0), &cfg, None);
        assert_eq!(rows.len(), 2);
        assert!(reference.fits_memory);
        // success (or at least near-success) should not degrade with budget
        assert!(rows[1].near_rate >= rows[0].near_rate);
    }
}
