//! Automated search (paper §2.3): the rewrite environment, MCTS with
//! UCT, and the multi-attempt experiment harness behind Figures 6–9.

pub mod env;
pub mod experiment;
pub mod mcts;

pub use env::{EnvAction, Episode, RewriteEnv, SearchOptions};
pub use experiment::{run_sweep, BudgetRow, ExperimentConfig};
pub use mcts::{search, MctsConfig, SearchResult};
