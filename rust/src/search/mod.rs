//! Automated search (paper §2.3): the rewrite environment, MCTS with
//! UCT, and the multi-attempt experiment harness behind Figures 6–9.
//!
//! The search entry points are thread-safe in the sense the service
//! executor (DESIGN.md §9) needs: [`search`] takes the environment by
//! shared reference and owns all mutable state, so root-parallel callers
//! run one search per worker thread with seeds derived by
//! [`worker_seed`] — distinct, reproducible streams per `(seed, worker)`.

pub mod env;
pub mod experiment;
pub mod mcts;

pub use env::{EnvAction, Episode, EvalMemo, RewriteEnv, SearchOptions};
pub use experiment::{run_sweep, BudgetRow, ExperimentConfig};
pub use mcts::{search, visit_entropy_of, Mcts, MctsConfig, SearchResult};

/// Derive worker `w`'s RNG seed from a request seed. Uses two rounds of
/// splitmix-style mixing so consecutive workers get uncorrelated streams,
/// and `worker_seed(s, 0) != s` so a single-worker executor run is still
/// distinguishable from a bare `search(env, budget, s, ..)` call.
pub fn worker_seed(seed: u64, worker: usize) -> u64 {
    let mut z = seed
        .wrapping_add(0x9E3779B97F4A7C15)
        .wrapping_add((worker as u64).wrapping_mul(0xBF58476D1CE4E5B9));
    for _ in 0..2 {
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
    }
    z
}

#[cfg(test)]
mod tests {
    use super::worker_seed;

    #[test]
    fn worker_seeds_are_distinct_and_deterministic() {
        let mut seen = std::collections::HashSet::new();
        for w in 0..64 {
            assert!(seen.insert(worker_seed(42, w)), "collision at worker {w}");
            assert_eq!(worker_seed(42, w), worker_seed(42, w));
        }
        assert_ne!(worker_seed(42, 0), worker_seed(43, 0));
        assert_ne!(worker_seed(42, 0), 42);
    }
}
