//! Rewriting environment exposed to the automated partitioner (paper
//! §2.2): a worklist of interesting values, group-level tile actions,
//! the infer-rest tactic, and cost-model evaluation of episodes.
//!
//! Two structure-exploitation mechanisms from the paper are modelled:
//!   * `cross_layer_tying` — emulates propagation "through subtly shared
//!     constants and other computations across layers" (§3): a decision
//!     on one layer's argument spreads to the same role in every layer.
//!     The paper calls this sharing brittle; Figure 9 disables it.
//!   * `grouping` — the robust replacement (Figure 8): named-scope layer
//!     groups expose a single decision set per repeated block, shrinking
//!     the action space itself.

use crate::cost::composite::{evaluate, CostWeights, Evaluation};
use crate::ir::{ArgKind, ValueId};
use crate::partir::actions::{action_valid, Action, DecisionState};
use crate::partir::dist::DistMap;
use crate::partir::mesh::AxisId;
use crate::partir::program::PartirProgram;
use crate::partir::propagate::PropStats;
use crate::sim::device::Device;

/// Search options.
#[derive(Debug, Clone)]
pub struct SearchOptions {
    /// Maximum explicit decisions per episode (paper: solutions needed
    /// 2–20 decisions).
    pub max_decisions: usize,
    /// Group repeated layers via named scopes (Fig 8).
    pub grouping: bool,
    /// Emulated cross-layer shared-dependency propagation (Fig 9 ablation).
    pub cross_layer_tying: bool,
    /// Run infer-rest before evaluating a terminal state (shards
    /// optimiser state / biases to match decided params).
    pub auto_infer_rest: bool,
}

impl Default for SearchOptions {
    fn default() -> Self {
        SearchOptions {
            max_decisions: 10,
            grouping: false,
            cross_layer_tying: true,
            auto_infer_rest: true,
        }
    }
}

/// A decision target: one worklist entry — either a single value or a
/// layer group of same-role values.
#[derive(Debug, Clone)]
pub struct Target {
    pub key: String,
    pub values: Vec<ValueId>,
}

/// Environment-level action (indices into the target list).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EnvAction {
    Tile { target: u32, dim: u8, axis: u8 },
    InferRest,
    Stop,
}

/// Strip per-layer indices from a scope-qualified argument name so that
/// `layer_3/attn/wq` and `layer_17/attn/wq` share the key
/// `layer_*/attn/wq` (Haiku-style named scopes, paper §3 "Scaling with
/// compiler hints").
pub fn role_key(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    let bytes = name.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        // Replace any digit run that follows '_' with '*'.
        if bytes[i] == b'_' && i + 1 < bytes.len() && bytes[i + 1].is_ascii_digit() {
            out.push('_');
            out.push('*');
            i += 1;
            while i < bytes.len() && bytes[i].is_ascii_digit() {
                i += 1;
            }
        } else {
            out.push(bytes[i] as char);
            i += 1;
        }
    }
    out
}

/// Per-search-run memo of terminal-state evaluations, keyed by
/// [`RewriteEnv::state_fingerprint`]. Scoped to one search run (one
/// program + mesh + device + weights), so entries never need
/// invalidation; size is bounded by the episode budget.
#[derive(Debug, Default)]
pub struct EvalMemo {
    map: std::collections::HashMap<u64, Evaluation>,
    /// Total evaluation requests routed through the memo.
    pub lookups: usize,
    /// Requests answered from the memo (full cost pipeline skipped).
    pub hits: usize,
}

impl EvalMemo {
    pub fn new() -> EvalMemo {
        EvalMemo::default()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// One search episode's mutable state.
#[derive(Clone)]
pub struct Episode {
    pub state: DecisionState,
    pub dm: DistMap,
    pub stats: PropStats,
    pub decisions: usize,
    pub done: bool,
}

pub struct RewriteEnv<'a> {
    pub program: &'a PartirProgram,
    pub device: Device,
    pub weights: CostWeights,
    pub options: SearchOptions,
    /// Decision targets (worklist entries / groups).
    pub targets: Vec<Target>,
    /// Decisions every episode starts from (user constraints pinned by a
    /// `Session`'s `Manual` tactic; empty for an unconstrained search).
    pub seed: DecisionState,
    /// The seed replayed once with propagation; cloned into every
    /// episode so `reset` is a flat memcpy, not a re-propagation.
    seed_dm: DistMap,
    seed_stats: PropStats,
    /// Baseline cost for reward normalisation: the seed state's cost
    /// (fully replicated when the seed is empty).
    pub base_cost: f64,
}

impl<'a> RewriteEnv<'a> {
    /// Build the environment. `worklist` is the candidate value list
    /// (typically all non-OptState args, or the learner's top-k).
    pub fn new(
        program: &'a PartirProgram,
        device: Device,
        weights: CostWeights,
        options: SearchOptions,
        worklist: &[ValueId],
    ) -> RewriteEnv<'a> {
        Self::with_seed(program, device, weights, options, worklist, DecisionState::default())
    }

    /// Like [`RewriteEnv::new`], but every episode starts from `seed`
    /// (already-taken decisions replayed with propagation), and rewards
    /// are normalised against the seed state's cost. This is how a
    /// `Session`'s `Manual` tactic constrains the search stage.
    pub fn with_seed(
        program: &'a PartirProgram,
        device: Device,
        weights: CostWeights,
        options: SearchOptions,
        worklist: &[ValueId],
        seed: DecisionState,
    ) -> RewriteEnv<'a> {
        let mut targets: Vec<Target> = Vec::new();
        let tie = options.grouping || options.cross_layer_tying;
        for &v in worklist {
            let name = &program.func.args[v.index()].name;
            let key = if tie { role_key(name) } else { name.clone() };
            if options.grouping {
                // one target per key, holding every member value
                if let Some(t) = targets.iter_mut().find(|t| t.key == key) {
                    t.values.push(v);
                    continue;
                }
                targets.push(Target { key, values: vec![v] });
            } else {
                targets.push(Target { key, values: vec![v] });
            }
        }
        let (seed_dm, seed_stats) = program.apply(&seed);
        let base = evaluate(program, &seed_dm, &device, &weights);
        RewriteEnv {
            program,
            device,
            weights,
            options,
            targets,
            seed,
            seed_dm,
            seed_stats,
            base_cost: base.cost,
        }
    }

    /// Default worklist: every function argument except optimiser state
    /// (which follows its parameter through infer-rest), exactly the
    /// paper's "weights and biases ... and model inputs".
    pub fn default_worklist(program: &PartirProgram) -> Vec<ValueId> {
        (0..program.func.num_args())
            .filter(|&i| program.func.args[i].kind != ArgKind::OptState)
            .map(|i| ValueId(i as u32))
            .collect()
    }

    pub fn reset(&self) -> Episode {
        let mut state = self.seed.clone();
        if state.atomic.is_empty() {
            // pre-size so hot-path inserts never reallocate
            state.atomic = crate::partir::actions::AtomicSet::with_capacity(
                self.program.func.num_values(),
            );
        }
        Episode {
            state,
            dm: self.seed_dm.clone(),
            stats: self.seed_stats.clone(),
            decisions: 0,
            done: false,
        }
    }

    /// The values affected by acting on `target` (group + tying expansion).
    fn expand_target(&self, target: u32) -> Vec<ValueId> {
        let t = &self.targets[target as usize];
        if self.options.grouping {
            return t.values.clone();
        }
        if self.options.cross_layer_tying {
            // spread to every arg sharing the role key
            let f = &self.program.func;
            return (0..f.num_args())
                .filter(|&i| {
                    f.args[i].kind != ArgKind::OptState && role_key(&f.args[i].name) == t.key
                })
                .map(|i| ValueId(i as u32))
                .collect();
        }
        t.values.clone()
    }

    /// Legal actions in `ep`'s current state.
    pub fn legal_actions(&self, ep: &Episode) -> Vec<EnvAction> {
        let mut out = Vec::new();
        if ep.done || ep.decisions >= self.options.max_decisions {
            return out;
        }
        let f = &self.program.func;
        let mesh = &self.program.mesh;
        for (ti, t) in self.targets.iter().enumerate() {
            let v = t.values[0];
            let rank = f.value_type(v).rank();
            for axis in mesh.searchable_axes() {
                for dim in 0..rank {
                    let a = Action::Tile { v, dim, axis };
                    if action_valid(f, mesh, &ep.dm, &ep.state, &a) {
                        out.push(EnvAction::Tile {
                            target: ti as u32,
                            dim: dim as u8,
                            axis: axis.0 as u8,
                        });
                    }
                }
            }
        }
        out.push(EnvAction::InferRest);
        out.push(EnvAction::Stop);
        out
    }

    /// Apply an action in place (incremental propagation).
    pub fn step(&self, ep: &mut Episode, a: EnvAction) {
        let f = &self.program.func;
        let mesh = &self.program.mesh;
        match a {
            EnvAction::Tile { target, dim, axis } => {
                let axis = AxisId(axis as usize);
                for v in self.expand_target(target) {
                    let act = Action::Tile { v, dim: dim as usize, axis };
                    if action_valid(f, mesh, &ep.dm, &ep.state, &act) {
                        ep.dm.set(v.index(), axis, dim as usize);
                        ep.state.actions.push(act);
                    }
                }
                ep.stats.stuck_nodes.clear();
                self.program.prop.forward(f, mesh, &mut ep.dm, &mut ep.stats);
                ep.decisions += 1;
            }
            EnvAction::InferRest => {
                ep.stats.stuck_nodes.clear();
                self.program.prop.infer_rest(f, mesh, &mut ep.dm, &mut ep.stats);
                ep.state.actions.push(Action::InferRest);
                ep.decisions += 1;
            }
            EnvAction::Stop => {
                ep.done = true;
            }
        }
        if ep.decisions >= self.options.max_decisions {
            ep.done = true;
        }
    }

    /// Canonical fingerprint of an episode's decision state: a stable
    /// hash of the distribution map it induced. Two episodes that reached
    /// the same per-value tiling assignment (regardless of action order)
    /// get the same key, and evaluation is a pure function of the map —
    /// which is what makes [`EvalMemo`] sound.
    pub fn state_fingerprint(&self, ep: &Episode) -> u64 {
        let mut h = crate::util::hash::Fnv64::new();
        h.usize(ep.dm.num_axes);
        for row in &ep.dm.d {
            h.bytes(row);
        }
        h.finish()
    }

    /// Like [`RewriteEnv::evaluate_episode`], but consults `memo` first:
    /// MCTS revisits of an identical terminal distribution skip the
    /// lower + liveness + roofline pipeline entirely.
    pub fn evaluate_episode_memo(&self, ep: &Episode, memo: &mut EvalMemo) -> Evaluation {
        let key = self.state_fingerprint(ep);
        memo.lookups += 1;
        if let Some(e) = memo.map.get(&key) {
            memo.hits += 1;
            return e.clone();
        }
        let e = self.evaluate_episode(ep);
        memo.map.insert(key, e.clone());
        e
    }

    /// Evaluate a terminal episode (applies auto infer-rest if enabled).
    pub fn evaluate_episode(&self, ep: &Episode) -> Evaluation {
        if self.options.auto_infer_rest {
            let mut dm = ep.dm.clone();
            let mut stats = PropStats::default();
            self.program.prop.infer_rest(
                &self.program.func,
                &self.program.mesh,
                &mut dm,
                &mut stats,
            );
            evaluate(self.program, &dm, &self.device, &self.weights)
        } else {
            evaluate(self.program, &ep.dm, &self.device, &self.weights)
        }
    }

    /// Normalised reward: improvement over the replicated baseline.
    pub fn reward(&self, eval: &Evaluation) -> f64 {
        ((self.base_cost - eval.cost) / self.base_cost.abs().max(1e-12)).clamp(-1.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::transformer::{build_transformer, TransformerConfig};
    use crate::partir::mesh::Mesh;

    fn env_for(layers: usize, opts: SearchOptions) -> (PartirProgram, Device) {
        let model = build_transformer(&TransformerConfig::tiny(layers));
        let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
        let _ = opts;
        (program, Device::tpu_v3())
    }

    #[test]
    fn role_key_strips_layer_indices() {
        assert_eq!(role_key("layer_3/attn/wq"), "layer_*/attn/wq");
        assert_eq!(role_key("layer_17/mlp/w1"), "layer_*/mlp/w1");
        assert_eq!(role_key("embed"), "embed");
        assert_eq!(role_key("round_2/edge_mlp/w1"), "round_*/edge_mlp/w1");
    }

    #[test]
    fn role_key_edge_cases() {
        // Trailing digit runs after '_' collapse, even at end of name.
        assert_eq!(role_key("dense_0"), "dense_*");
        assert_eq!(role_key("dense_12"), "dense_*");
        assert_eq!(role_key("w_007"), "w_*");
        // Multi-digit indices deep in a scope path.
        assert_eq!(role_key("layer_17/attn/wq"), "layer_*/attn/wq");
        assert_eq!(role_key("block_3/layer_12/mlp/w2"), "block_*/layer_*/mlp/w2");
        // Names with no scope separator at all.
        assert_eq!(role_key("pos"), "pos");
        assert_eq!(role_key("lnf_g"), "lnf_g");
        // Digits NOT preceded by '_' are structural, not indices.
        assert_eq!(role_key("fc1"), "fc1");
        assert_eq!(role_key("conv2d/w"), "conv2d/w");
        // Digit run followed by more name: only the run collapses.
        assert_eq!(role_key("a_1b/c_2"), "a_*b/c_*");
        // Multiple underscore-digit runs in one segment.
        assert_eq!(role_key("x_1_2"), "x_*_*");
        // Trailing underscore and bare underscore-digit names.
        assert_eq!(role_key("x_"), "x_");
        assert_eq!(role_key("_5"), "_*");
        // Adam optimiser-state suffixes keep their role distinct.
        assert_eq!(role_key("layer_3/mlp/w1.adam_m"), "layer_*/mlp/w1.adam_m");
        // Empty string is a no-op.
        assert_eq!(role_key(""), "");
        // Same role across layers maps to the same key; different roles don't.
        assert_eq!(role_key("layer_0/attn/wq"), role_key("layer_31/attn/wq"));
        assert_ne!(role_key("layer_0/attn/wq"), role_key("layer_0/attn/wk"));
    }

    #[test]
    fn grouping_collapses_targets_across_layers() {
        let (program, device) = env_for(4, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let ungrouped = RewriteEnv::new(
            &program,
            device.clone(),
            CostWeights::default(),
            SearchOptions { grouping: false, cross_layer_tying: false, ..Default::default() },
            &wl,
        );
        let grouped = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions { grouping: true, ..Default::default() },
            &wl,
        );
        assert!(grouped.targets.len() < ungrouped.targets.len() / 2);
        // grouped: 16 per-layer roles + embed/pos/lnf_g/lnf_b + mask/tokens/targets
        assert_eq!(grouped.targets.len(), 16 + 4 + 3);
    }

    #[test]
    fn step_tile_propagates_and_counts_decisions() {
        let (program, device) = env_for(2, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let mut ep = env.reset();
        let acts = env.legal_actions(&ep);
        assert!(acts.len() > 10);
        // find the wq target and tile dim 1
        let ti = env
            .targets
            .iter()
            .position(|t| t.key.ends_with("attn/wq"))
            .unwrap();
        env.step(&mut ep, EnvAction::Tile { target: ti as u32, dim: 1, axis: 0 });
        assert_eq!(ep.decisions, 1);
        // cross-layer tying: BOTH layers' wq tiled
        let tiled_wqs = (0..program.func.num_args())
            .filter(|&i| {
                program.func.args[i].name.ends_with("wq") && ep.dm.is_tiled(i)
            })
            .count();
        assert_eq!(tiled_wqs, 2);
    }

    #[test]
    fn eval_memo_skips_repeat_terminal_states() {
        let (program, device) = env_for(1, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let mut memo = EvalMemo::new();

        // Two episodes that stop immediately share a terminal state.
        let mut ep1 = env.reset();
        env.step(&mut ep1, EnvAction::Stop);
        let mut ep2 = env.reset();
        env.step(&mut ep2, EnvAction::Stop);
        assert_eq!(env.state_fingerprint(&ep1), env.state_fingerprint(&ep2));

        let e1 = env.evaluate_episode_memo(&ep1, &mut memo);
        let e2 = env.evaluate_episode_memo(&ep2, &mut memo);
        assert_eq!(memo.lookups, 2);
        assert_eq!(memo.hits, 1);
        assert_eq!(memo.len(), 1);
        assert_eq!(e1.cost, e2.cost);
        // The memoized answer matches a fresh evaluation exactly.
        let fresh = env.evaluate_episode(&ep2);
        assert_eq!(e2.cost, fresh.cost);
        assert_eq!(e2.collectives, fresh.collectives);

        // A different terminal state is a different key.
        let mut ep3 = env.reset();
        let acts = env.legal_actions(&ep3);
        let tile = acts
            .iter()
            .find(|a| matches!(a, EnvAction::Tile { .. }))
            .copied()
            .expect("some tile action must be legal");
        env.step(&mut ep3, tile);
        env.step(&mut ep3, EnvAction::Stop);
        assert_ne!(env.state_fingerprint(&ep3), env.state_fingerprint(&ep1));
        let _ = env.evaluate_episode_memo(&ep3, &mut memo);
        assert_eq!(memo.hits, 1);
        assert_eq!(memo.len(), 2);
    }

    #[test]
    fn stop_ends_episode_and_reward_is_normalised() {
        let (program, device) = env_for(1, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let mut ep = env.reset();
        env.step(&mut ep, EnvAction::Stop);
        assert!(ep.done);
        assert!(env.legal_actions(&ep).is_empty());
        let eval = env.evaluate_episode(&ep);
        let r = env.reward(&eval);
        assert!((-1.0..=1.0).contains(&r));
    }
}
