//! Rewriting environment exposed to the automated partitioner (paper
//! §2.2): a worklist of interesting values, group-level tile actions,
//! the infer-rest tactic, and cost-model evaluation of episodes.
//!
//! Two structure-exploitation mechanisms from the paper are modelled:
//!   * `cross_layer_tying` — emulates propagation "through subtly shared
//!     constants and other computations across layers" (§3): a decision
//!     on one layer's argument spreads to the same role in every layer.
//!     The paper calls this sharing brittle; Figure 9 disables it.
//!   * `grouping` — the robust replacement (Figure 8): named-scope layer
//!     groups expose a single decision set per repeated block, shrinking
//!     the action space itself.

use crate::cost::composite::{evaluate, evaluate_pipelined, CostLedger, CostWeights, Evaluation};
use crate::ir::{ArgKind, ValueId};
use crate::partir::actions::{action_valid, Action, DecisionState};
use crate::partir::dist::{DistMap, UNKNOWN};
use crate::partir::mesh::AxisId;
use crate::partir::program::PartirProgram;
use crate::partir::propagate::{FrontierScratch, PropStats, StuckSet};
use crate::pipeline::PipelineSpec;
use crate::sim::device::Device;
use std::collections::HashMap;

/// Search options.
#[derive(Debug, Clone)]
pub struct SearchOptions {
    /// Maximum explicit decisions per episode (paper: solutions needed
    /// 2–20 decisions).
    pub max_decisions: usize,
    /// Group repeated layers via named scopes (Fig 8).
    pub grouping: bool,
    /// Emulated cross-layer shared-dependency propagation (Fig 9 ablation).
    pub cross_layer_tying: bool,
    /// Run infer-rest before evaluating a terminal state (shards
    /// optimiser state / biases to match decided params).
    pub auto_infer_rest: bool,
}

impl Default for SearchOptions {
    fn default() -> Self {
        SearchOptions {
            max_decisions: 10,
            grouping: false,
            cross_layer_tying: true,
            auto_infer_rest: true,
        }
    }
}

/// A decision target: one worklist entry — either a single value or a
/// layer group of same-role values.
#[derive(Debug, Clone)]
pub struct Target {
    pub key: String,
    pub values: Vec<ValueId>,
}

/// Environment-level action (indices into the target list).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EnvAction {
    Tile { target: u32, dim: u8, axis: u8 },
    /// Move stage-cut `boundary` by `delta` strides (DESIGN.md §11) —
    /// only offered when a pipeline is active. Cuts stay strictly
    /// between their neighbours, so every move keeps the stage
    /// assignment valid without a legality re-check.
    CutMove { boundary: u8, delta: i8 },
    InferRest,
    Stop,
}

/// Active pipeline configuration for a search (see
/// [`RewriteEnv::set_pipeline`]): the spec whose cuts seed every
/// episode, and the node-index stride one `CutMove` action travels.
#[derive(Debug, Clone)]
pub struct PipelineContext {
    pub spec: PipelineSpec,
    /// Stride of one cut move: `max(1, nodes / (8 * stages))`, so a
    /// handful of moves can traverse a stage interval without flooding
    /// the branching factor with single-node steps.
    pub stride: usize,
}

/// Strip per-layer indices from a scope-qualified argument name so that
/// `layer_3/attn/wq` and `layer_17/attn/wq` share the key
/// `layer_*/attn/wq` (Haiku-style named scopes, paper §3 "Scaling with
/// compiler hints").
pub fn role_key(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    let bytes = name.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        // Replace any digit run that follows '_' with '*'.
        if bytes[i] == b'_' && i + 1 < bytes.len() && bytes[i + 1].is_ascii_digit() {
            out.push('_');
            out.push('*');
            i += 1;
            while i < bytes.len() && bytes[i].is_ascii_digit() {
                i += 1;
            }
        } else {
            out.push(bytes[i] as char);
            i += 1;
        }
    }
    out
}

/// Default [`EvalMemo`] entry cap: ~32k evaluations (a few MB) covers
/// every realistic per-request budget while bounding a runaway one.
pub const EVAL_MEMO_DEFAULT_CAP: usize = 32_768;

/// Per-search-run memo of terminal-state evaluations, keyed by
/// [`RewriteEnv::state_fingerprint`]. Scoped to one search run (one
/// program + mesh + device + weights), so entries never need
/// invalidation. Size is bounded by an entry cap with LRU-ish batch
/// eviction: entries carry a last-use tick, and when the cap is hit the
/// least-recently-used half is dropped in one deterministic sweep (so a
/// fixed seed still reproduces identical hit counts). Also owns the
/// scratch map the auto-infer-rest evaluation path reuses, so a memo
/// miss costs zero fresh allocations.
#[derive(Debug)]
pub struct EvalMemo {
    map: HashMap<u64, (Evaluation, u64)>,
    cap: usize,
    tick: u64,
    /// Total evaluation requests routed through the memo.
    pub lookups: usize,
    /// Requests answered from the memo (full cost pipeline skipped).
    pub hits: usize,
    /// Entries dropped by cap eviction.
    pub evictions: usize,
    /// Reused infer-rest scratch map (lazily sized to the program).
    scratch_dm: Option<DistMap>,
}

impl Default for EvalMemo {
    fn default() -> Self {
        EvalMemo::new()
    }
}

impl EvalMemo {
    pub fn new() -> EvalMemo {
        EvalMemo::with_cap(EVAL_MEMO_DEFAULT_CAP)
    }

    pub fn with_cap(cap: usize) -> EvalMemo {
        EvalMemo {
            map: HashMap::new(),
            cap: cap.max(2),
            tick: 0,
            lookups: 0,
            hits: 0,
            evictions: 0,
            scratch_dm: None,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    fn insert(&mut self, key: u64, eval: Evaluation) {
        if self.map.len() >= self.cap {
            // LRU-ish batch eviction: drop the least-recently-used half
            // (median-tick split; ticks are unique, so deterministic).
            let mut ticks: Vec<u64> = self.map.values().map(|(_, t)| *t).collect();
            let mid = ticks.len() / 2;
            let (_, median, _) = ticks.select_nth_unstable(mid);
            let median = *median;
            let before = self.map.len();
            self.map.retain(|_, (_, t)| *t >= median);
            self.evictions += before - self.map.len();
        }
        self.tick += 1;
        self.map.insert(key, (eval, self.tick));
    }
}

/// One search episode's mutable state.
pub struct Episode {
    pub state: DecisionState,
    pub dm: DistMap,
    /// Stage-cut positions (empty unless the env has a pipeline).
    /// Cut moves mutate these, never the distribution map — a cut is
    /// an inter-op choice layered over the intra-op tiling.
    pub cuts: Vec<u32>,
    /// Stuck-node set w.r.t. `dm`, maintained incrementally.
    pub stuck: StuckSet,
    /// Total value-axis assignments made by propagation so far.
    pub assigned: usize,
    pub decisions: usize,
    pub done: bool,
    /// The previous action was `InferRest` (an immediate repeat would be
    /// a no-op, so `legal_actions` stops offering it).
    pub last_infer_rest: bool,
    /// Reusable dirty-frontier queue for incremental sweeps.
    scratch: FrontierScratch,
    /// Per-episode cost ledger (attached by
    /// [`RewriteEnv::attach_ledger`]; `None` until then). The ledger is
    /// evaluation *scratch*, not episode identity: its cached terms
    /// describe whatever map it last evaluated, and a refresh re-syncs
    /// it to any target exactly — so `Clone` never copies it (see the
    /// impl below) and a stale ledger is never wrong, only less warm.
    pub ledger: Option<Box<CostLedger>>,
}

/// Manual impl so `clone_from` reuses every buffer: the MCTS episode
/// loop resets its scratch episode from the root this way, making
/// per-episode reset a set of memcpys instead of fresh allocations.
///
/// The cost ledger deliberately does NOT propagate through `Clone`:
/// `clone` starts without one and `clone_from` keeps the destination's
/// ledger untouched. Copying it would memcpy every per-node term on
/// every episode reset for nothing — the ledger re-syncs itself by
/// diffing at the next evaluation, and its answers are bit-identical
/// whatever state it starts from.
impl Clone for Episode {
    fn clone(&self) -> Episode {
        Episode {
            state: self.state.clone(),
            dm: self.dm.clone(),
            cuts: self.cuts.clone(),
            stuck: self.stuck.clone(),
            assigned: self.assigned,
            decisions: self.decisions,
            done: self.done,
            last_infer_rest: self.last_infer_rest,
            scratch: self.scratch.clone(),
            ledger: None,
        }
    }

    fn clone_from(&mut self, src: &Episode) {
        self.state.clone_from(&src.state);
        self.dm.d.clone_from(&src.dm.d);
        self.dm.num_axes = src.dm.num_axes;
        self.cuts.clone_from(&src.cuts);
        self.stuck.clone_from(&src.stuck);
        self.assigned = src.assigned;
        self.decisions = src.decisions;
        self.done = src.done;
        self.last_infer_rest = src.last_infer_rest;
        self.scratch.clone_from(&src.scratch);
        // self.ledger intentionally kept (see the impl-level comment).
    }
}

/// One statically valid tile candidate for a target: rank and
/// divisibility are checked once at env construction, so the per-step
/// legality filter only tests the dynamic parts (atomic set, axis free,
/// dim not taken) against the episode's current map.
#[derive(Debug, Clone, Copy)]
struct CandidateTile {
    action: EnvAction,
    dim: u8,
    axis: AxisId,
}

pub struct RewriteEnv<'a> {
    pub program: &'a PartirProgram,
    pub device: Device,
    pub weights: CostWeights,
    pub options: SearchOptions,
    /// Decision targets (worklist entries / groups).
    pub targets: Vec<Target>,
    /// Values an action on target `i` spreads to (group membership /
    /// cross-layer tying resolved ONCE — the old code rebuilt role-key
    /// strings for every arg on every step).
    expanded: Vec<Vec<ValueId>>,
    /// Statically valid tile candidates per target.
    candidates: Vec<Vec<CandidateTile>>,
    /// Decisions every episode starts from (user constraints pinned by a
    /// `Session`'s `Manual` tactic; empty for an unconstrained search).
    pub seed: DecisionState,
    /// The seed replayed once with propagation; cloned into every
    /// episode so `reset` is a flat memcpy, not a re-propagation.
    seed_dm: DistMap,
    seed_stuck: StuckSet,
    seed_assigned: usize,
    seed_last_infer: bool,
    /// Baseline cost for reward normalisation: the seed state's cost
    /// (fully replicated when the seed is empty).
    pub base_cost: f64,
    /// Active pipeline (None = pure SPMD search).
    pub pipeline: Option<PipelineContext>,
}

impl<'a> RewriteEnv<'a> {
    /// Build the environment. `worklist` is the candidate value list
    /// (typically all non-OptState args, or the learner's top-k).
    pub fn new(
        program: &'a PartirProgram,
        device: Device,
        weights: CostWeights,
        options: SearchOptions,
        worklist: &[ValueId],
    ) -> RewriteEnv<'a> {
        Self::with_seed(program, device, weights, options, worklist, DecisionState::default())
    }

    /// Like [`RewriteEnv::new`], but every episode starts from `seed`
    /// (already-taken decisions replayed with propagation), and rewards
    /// are normalised against the seed state's cost. This is how a
    /// `Session`'s `Manual` tactic constrains the search stage.
    pub fn with_seed(
        program: &'a PartirProgram,
        device: Device,
        weights: CostWeights,
        options: SearchOptions,
        worklist: &[ValueId],
        seed: DecisionState,
    ) -> RewriteEnv<'a> {
        let f = &program.func;
        let mesh = &program.mesh;
        let tie = options.grouping || options.cross_layer_tying;
        // Role keys for every arg, computed ONCE (the old hot path
        // rebuilt these strings per arg per step).
        let keys: Vec<String> = (0..f.num_args())
            .map(|i| if tie { role_key(&f.args[i].name) } else { f.args[i].name.clone() })
            .collect();
        let mut targets: Vec<Target> = Vec::new();
        if options.grouping {
            // One target per key (first-seen order), holding every member.
            let mut by_key: HashMap<&str, usize> = HashMap::new();
            for &v in worklist {
                let key = keys[v.index()].as_str();
                match by_key.get(key) {
                    Some(&ti) => targets[ti].values.push(v),
                    None => {
                        by_key.insert(key, targets.len());
                        targets.push(Target { key: key.to_string(), values: vec![v] });
                    }
                }
            }
        } else {
            for &v in worklist {
                targets.push(Target { key: keys[v.index()].clone(), values: vec![v] });
            }
        }
        // Cross-layer tying expansion, resolved once: role key -> every
        // non-OptState arg sharing it.
        let mut role_members: HashMap<&str, Vec<ValueId>> = HashMap::new();
        if !options.grouping && options.cross_layer_tying {
            for i in 0..f.num_args() {
                if f.args[i].kind != ArgKind::OptState {
                    role_members.entry(keys[i].as_str()).or_default().push(ValueId(i as u32));
                }
            }
        }
        let expanded: Vec<Vec<ValueId>> = targets
            .iter()
            .map(|t| {
                if !options.grouping && options.cross_layer_tying {
                    role_members.get(t.key.as_str()).cloned().unwrap_or_default()
                } else {
                    t.values.clone()
                }
            })
            .collect();
        // Static tile candidates: rank + divisibility per representative
        // value, against the searchable axes (fixed for the env's life).
        let candidates: Vec<Vec<CandidateTile>> = targets
            .iter()
            .enumerate()
            .map(|(ti, t)| {
                let v = t.values[0];
                let ty = f.value_type(v);
                let mut out = Vec::new();
                for axis in mesh.searchable_axes() {
                    for dim in 0..ty.rank() {
                        if ty.dims[dim] % mesh.size(axis) == 0 {
                            out.push(CandidateTile {
                                action: EnvAction::Tile {
                                    target: ti as u32,
                                    dim: dim as u8,
                                    axis: axis.0 as u8,
                                },
                                dim: dim as u8,
                                axis,
                            });
                        }
                    }
                }
                out
            })
            .collect();
        let (seed_dm, seed_stats) = program.apply(&seed);
        let mut seed_stuck = StuckSet::with_capacity(f.num_nodes());
        for &n in &program.stuck_set(&seed_dm) {
            seed_stuck.insert(n);
        }
        let seed_last_infer = matches!(seed.actions.last(), Some(Action::InferRest));
        let base = evaluate(program, &seed_dm, &device, &weights);
        RewriteEnv {
            program,
            device,
            weights,
            options,
            targets,
            expanded,
            candidates,
            seed,
            seed_dm,
            seed_stuck,
            seed_assigned: seed_stats.assigned,
            seed_last_infer,
            base_cost: base.cost,
            pipeline: None,
        }
    }

    /// Activate a pipeline for this search: every episode starts from
    /// `spec.cuts`, `CutMove` actions become legal alongside tile
    /// actions, and evaluation routes through the 1F1B pricing. The
    /// reward baseline is re-normalised against the pipelined seed cost
    /// (the flat cost is not comparable to a makespan-based one).
    pub fn set_pipeline(&mut self, spec: PipelineSpec) {
        let n = self.program.func.num_nodes();
        let stride = (n / (8 * spec.stages())).max(1);
        let base =
            evaluate_pipelined(self.program, &self.seed_dm, &self.device, &self.weights, Some(&spec));
        self.base_cost = base.cost;
        self.pipeline = Some(PipelineContext { spec, stride });
    }

    /// The episode's pipeline spec — the env's axis/microbatch config
    /// with the episode's CURRENT cut vector (None when no pipeline).
    fn episode_spec(&self, ep: &Episode) -> Option<PipelineSpec> {
        self.pipeline.as_ref().map(|p| PipelineSpec {
            axis: p.spec.axis,
            microbatches: p.spec.microbatches,
            cuts: ep.cuts.clone(),
        })
    }

    /// Default worklist: every function argument except optimiser state
    /// (which follows its parameter through infer-rest), exactly the
    /// paper's "weights and biases ... and model inputs".
    pub fn default_worklist(program: &PartirProgram) -> Vec<ValueId> {
        (0..program.func.num_args())
            .filter(|&i| program.func.args[i].kind != ArgKind::OptState)
            .map(|i| ValueId(i as u32))
            .collect()
    }

    pub fn reset(&self) -> Episode {
        let mut state = self.seed.clone();
        if state.atomic.is_empty() {
            // pre-size so hot-path inserts never reallocate
            state.atomic = crate::partir::actions::AtomicSet::with_capacity(
                self.program.func.num_values(),
            );
        }
        Episode {
            state,
            dm: self.seed_dm.clone(),
            cuts: self.pipeline.as_ref().map(|p| p.spec.cuts.clone()).unwrap_or_default(),
            stuck: self.seed_stuck.clone(),
            assigned: self.seed_assigned,
            decisions: 0,
            done: false,
            last_infer_rest: self.seed_last_infer,
            scratch: FrontierScratch::with_capacity(self.program.func.num_nodes()),
            ledger: None,
        }
    }

    /// Attach a cost ledger to `ep` (no-op when one is already there):
    /// subsequent [`RewriteEnv::evaluate_episode_ledger`] and memo-miss
    /// evaluations run incrementally instead of re-lowering the whole
    /// program. Built from the seed map so the first evaluation already
    /// diffs, not rebuilds.
    pub fn attach_ledger(&self, ep: &mut Episode) {
        if ep.ledger.is_none() {
            ep.ledger = Some(Box::new(CostLedger::new(
                self.program,
                &self.seed_dm,
                self.device.clone(),
                self.weights.clone(),
            )));
        }
    }

    /// Legal actions in `ep`'s current state, filtered from the
    /// precomputed candidate table into a caller-provided buffer — no
    /// string work, no allocation (the buffer is reused across calls).
    /// `InferRest` is only offered when the previous action wasn't one
    /// (a consecutive repeat is a no-op that would burn a decision and
    /// bloat the branching factor).
    pub fn legal_actions_into(&self, ep: &Episode, out: &mut Vec<EnvAction>) {
        out.clear();
        if ep.done || ep.decisions >= self.options.max_decisions {
            return;
        }
        for (ti, t) in self.targets.iter().enumerate() {
            let v = t.values[0];
            if ep.state.is_atomic(v) {
                continue;
            }
            let row = &ep.dm.d[v.index()];
            for c in &self.candidates[ti] {
                if row[c.axis.0] == UNKNOWN && !ep.dm.dim_taken(v.index(), c.axis, c.dim as usize) {
                    out.push(c.action);
                }
            }
        }
        if let Some(p) = &self.pipeline {
            // Cut moves: shift one boundary by ±stride, staying strictly
            // between its neighbours (stages never empty out).
            let n = self.program.func.num_nodes() as i64;
            let stride = p.stride as i64;
            for (b, &c) in ep.cuts.iter().enumerate() {
                let prev = if b == 0 { 0 } else { ep.cuts[b - 1] as i64 };
                let next = if b + 1 == ep.cuts.len() { n } else { ep.cuts[b + 1] as i64 };
                for delta in [-1i8, 1] {
                    let nc = c as i64 + delta as i64 * stride;
                    if nc > prev && nc < next {
                        out.push(EnvAction::CutMove { boundary: b as u8, delta });
                    }
                }
            }
        }
        if !ep.last_infer_rest {
            out.push(EnvAction::InferRest);
        }
        out.push(EnvAction::Stop);
    }

    /// Allocating convenience form of [`RewriteEnv::legal_actions_into`].
    pub fn legal_actions(&self, ep: &Episode) -> Vec<EnvAction> {
        let mut out = Vec::new();
        self.legal_actions_into(ep, &mut out);
        out
    }

    /// Apply an action in place. Tile actions propagate incrementally
    /// from the dirty-value frontier (the values the action touched)
    /// instead of re-sweeping the whole program; a debug build
    /// cross-checks every incremental sweep against the full pass.
    pub fn step(&self, ep: &mut Episode, a: EnvAction) {
        let f = &self.program.func;
        let mesh = &self.program.mesh;
        let prop = &self.program.prop;
        match a {
            EnvAction::Tile { target, dim, axis } => {
                let axis = AxisId(axis as usize);
                let dim = dim as usize;
                for &v in &self.expanded[target as usize] {
                    let act = Action::Tile { v, dim, axis };
                    if action_valid(f, mesh, &ep.dm, &ep.state, &act) {
                        ep.dm.set(v.index(), axis, dim);
                        ep.state.actions.push(act);
                        prop.seed_dirty(f, &mut ep.scratch, v);
                    }
                }
                #[cfg(debug_assertions)]
                let check_dm = ep.dm.clone();
                prop.forward_from(
                    f,
                    mesh,
                    &mut ep.dm,
                    &mut ep.stuck,
                    &mut ep.assigned,
                    &mut ep.scratch,
                );
                #[cfg(debug_assertions)]
                self.check_incremental(check_dm, ep);
                ep.decisions += 1;
                ep.last_infer_rest = false;
            }
            EnvAction::CutMove { boundary, delta } => {
                let p = self.pipeline.as_ref().expect("CutMove requires an active pipeline");
                let b = boundary as usize;
                let nc = (ep.cuts[b] as i64 + delta as i64 * p.stride as i64) as u32;
                #[cfg(debug_assertions)]
                {
                    let prev = if b == 0 { 0 } else { ep.cuts[b - 1] };
                    let next = if b + 1 == ep.cuts.len() {
                        self.program.func.num_nodes() as u32
                    } else {
                        ep.cuts[b + 1]
                    };
                    debug_assert!(nc > prev && nc < next, "illegal cut move {nc} in ({prev},{next})");
                }
                ep.cuts[b] = nc;
                // The distribution map is untouched: a cut move re-bins
                // per-node terms, it never re-tiles a value.
                ep.decisions += 1;
                ep.last_infer_rest = false;
            }
            EnvAction::InferRest => {
                let mut stats = PropStats::default();
                prop.infer_rest_settle(f, mesh, &mut ep.dm, &mut stats);
                ep.assigned += stats.assigned;
                ep.stuck.rebuild(&stats.stuck_nodes);
                ep.state.actions.push(Action::InferRest);
                ep.decisions += 1;
                ep.last_infer_rest = true;
            }
            EnvAction::Stop => {
                ep.done = true;
            }
        }
        if ep.decisions >= self.options.max_decisions {
            ep.done = true;
        }
    }

    /// Debug-build cross-check: the incremental sweep must be
    /// bit-identical to a full forward pass from the same post-action
    /// map, both in the distribution map and in the stuck set.
    #[cfg(debug_assertions)]
    fn check_incremental(&self, mut full_dm: DistMap, ep: &Episode) {
        let mut stats = PropStats::default();
        self.program.prop.forward(&self.program.func, &self.program.mesh, &mut full_dm, &mut stats);
        assert_eq!(full_dm, ep.dm, "incremental forward diverged from the full pass (dm)");
        assert_eq!(
            stats.stuck_nodes,
            ep.stuck.to_sorted_vec(),
            "incremental stuck set diverged from the full pass"
        );
    }

    /// Canonical fingerprint of an episode's decision state: a stable
    /// hash of the distribution map it induced. Two episodes that reached
    /// the same per-value tiling assignment (regardless of action order)
    /// get the same key, and evaluation is a pure function of the map —
    /// which is what makes [`EvalMemo`] sound.
    pub fn state_fingerprint(&self, ep: &Episode) -> u64 {
        let mut h = crate::util::hash::Fnv64::new();
        h.usize(ep.dm.num_axes);
        for row in &ep.dm.d {
            h.bytes(row);
        }
        if let Some(p) = &self.pipeline {
            // Pipelined evaluation is a function of (map, cuts, M, axis):
            // fold the extra inputs so the memo stays sound. Without a
            // pipeline the fingerprint is unchanged (same keys as ever).
            h.usize(p.spec.axis).usize(p.spec.microbatches).usize(ep.cuts.len());
            for &c in &ep.cuts {
                h.u64(c as u64);
            }
        }
        h.finish()
    }

    /// Like [`RewriteEnv::evaluate_episode`], but tiered: the memo is
    /// probed first (an identical terminal distribution costs one hash),
    /// and a miss is answered by the episode's incremental cost ledger
    /// when one is attached — only then does the full lower + liveness +
    /// roofline pipeline run. The memo is thus the second-level cache
    /// over the ledger, which is itself the fast path over the full
    /// pipeline. Ledger answers are bit-identical to full ones (debug
    /// builds assert it on every miss), so the tiering can never change
    /// a search result. Ledger-less misses reuse the memo's scratch map
    /// for the auto-infer-rest pass, so the steady state allocates
    /// nothing either way.
    pub fn evaluate_episode_memo(&self, ep: &mut Episode, memo: &mut EvalMemo) -> Evaluation {
        let key = self.state_fingerprint(ep);
        memo.lookups += 1;
        memo.tick += 1;
        let tick = memo.tick;
        if let Some((e, t)) = memo.map.get_mut(&key) {
            memo.hits += 1;
            *t = tick; // touch for LRU-ish eviction
            return e.clone();
        }
        let e = if ep.ledger.is_some() {
            self.ledger_evaluation(ep)
        } else if self.options.auto_infer_rest {
            let spec = self.episode_spec(ep);
            let dm = memo.scratch_dm.get_or_insert_with(|| ep.dm.clone());
            dm.d.clone_from(&ep.dm.d);
            dm.num_axes = ep.dm.num_axes;
            let mut stats = PropStats::default();
            self.program.prop.infer_rest(&self.program.func, &self.program.mesh, dm, &mut stats);
            evaluate_pipelined(self.program, dm, &self.device, &self.weights, spec.as_ref())
        } else {
            let spec = self.episode_spec(ep);
            evaluate_pipelined(self.program, &ep.dm, &self.device, &self.weights, spec.as_ref())
        };
        memo.insert(key, e.clone());
        e
    }

    /// Evaluate a terminal episode through its cost ledger (attached on
    /// demand): O(changed nodes) instead of a full re-lowering, with the
    /// same auto-infer-rest semantics as [`RewriteEnv::evaluate_episode`]
    /// and a bit-identical result.
    pub fn evaluate_episode_ledger(&self, ep: &mut Episode) -> Evaluation {
        self.attach_ledger(ep);
        self.ledger_evaluation(ep)
    }

    /// The shared ledger evaluation path (`ep.ledger` must be attached).
    /// Debug builds cross-check every answer against the full pipeline,
    /// to the bit.
    fn ledger_evaluation(&self, ep: &mut Episode) -> Evaluation {
        let spec = self.episode_spec(ep);
        let ledger = ep.ledger.as_mut().expect("ledger_evaluation needs an attached ledger");
        let e =
            ledger.evaluate_map(self.program, &ep.dm, self.options.auto_infer_rest, spec.as_ref());
        #[cfg(debug_assertions)]
        {
            let full = self.evaluate_episode(ep);
            assert_eq!(e, full, "ledger evaluation diverged from the full pipeline");
            assert_eq!(
                e.cost.to_bits(),
                full.cost.to_bits(),
                "ledger cost must match the full pipeline to the bit"
            );
        }
        e
    }

    /// Evaluate a terminal episode (applies auto infer-rest if enabled).
    pub fn evaluate_episode(&self, ep: &Episode) -> Evaluation {
        let spec = self.episode_spec(ep);
        if self.options.auto_infer_rest {
            let mut dm = ep.dm.clone();
            let mut stats = PropStats::default();
            self.program.prop.infer_rest(
                &self.program.func,
                &self.program.mesh,
                &mut dm,
                &mut stats,
            );
            evaluate_pipelined(self.program, &dm, &self.device, &self.weights, spec.as_ref())
        } else {
            evaluate_pipelined(self.program, &ep.dm, &self.device, &self.weights, spec.as_ref())
        }
    }

    /// Normalised reward: improvement over the replicated baseline.
    pub fn reward(&self, eval: &Evaluation) -> f64 {
        ((self.base_cost - eval.cost) / self.base_cost.abs().max(1e-12)).clamp(-1.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::transformer::{build_transformer, TransformerConfig};
    use crate::partir::mesh::Mesh;

    fn env_for(layers: usize, opts: SearchOptions) -> (PartirProgram, Device) {
        let model = build_transformer(&TransformerConfig::tiny(layers));
        let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
        let _ = opts;
        (program, Device::tpu_v3())
    }

    #[test]
    fn role_key_strips_layer_indices() {
        assert_eq!(role_key("layer_3/attn/wq"), "layer_*/attn/wq");
        assert_eq!(role_key("layer_17/mlp/w1"), "layer_*/mlp/w1");
        assert_eq!(role_key("embed"), "embed");
        assert_eq!(role_key("round_2/edge_mlp/w1"), "round_*/edge_mlp/w1");
    }

    #[test]
    fn role_key_edge_cases() {
        // Trailing digit runs after '_' collapse, even at end of name.
        assert_eq!(role_key("dense_0"), "dense_*");
        assert_eq!(role_key("dense_12"), "dense_*");
        assert_eq!(role_key("w_007"), "w_*");
        // Multi-digit indices deep in a scope path.
        assert_eq!(role_key("layer_17/attn/wq"), "layer_*/attn/wq");
        assert_eq!(role_key("block_3/layer_12/mlp/w2"), "block_*/layer_*/mlp/w2");
        // Names with no scope separator at all.
        assert_eq!(role_key("pos"), "pos");
        assert_eq!(role_key("lnf_g"), "lnf_g");
        // Digits NOT preceded by '_' are structural, not indices.
        assert_eq!(role_key("fc1"), "fc1");
        assert_eq!(role_key("conv2d/w"), "conv2d/w");
        // Digit run followed by more name: only the run collapses.
        assert_eq!(role_key("a_1b/c_2"), "a_*b/c_*");
        // Multiple underscore-digit runs in one segment.
        assert_eq!(role_key("x_1_2"), "x_*_*");
        // Trailing underscore and bare underscore-digit names.
        assert_eq!(role_key("x_"), "x_");
        assert_eq!(role_key("_5"), "_*");
        // Adam optimiser-state suffixes keep their role distinct.
        assert_eq!(role_key("layer_3/mlp/w1.adam_m"), "layer_*/mlp/w1.adam_m");
        // Empty string is a no-op.
        assert_eq!(role_key(""), "");
        // Same role across layers maps to the same key; different roles don't.
        assert_eq!(role_key("layer_0/attn/wq"), role_key("layer_31/attn/wq"));
        assert_ne!(role_key("layer_0/attn/wq"), role_key("layer_0/attn/wk"));
    }

    #[test]
    fn grouping_collapses_targets_across_layers() {
        let (program, device) = env_for(4, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let ungrouped = RewriteEnv::new(
            &program,
            device.clone(),
            CostWeights::default(),
            SearchOptions { grouping: false, cross_layer_tying: false, ..Default::default() },
            &wl,
        );
        let grouped = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions { grouping: true, ..Default::default() },
            &wl,
        );
        assert!(grouped.targets.len() < ungrouped.targets.len() / 2);
        // grouped: 16 per-layer roles + embed/pos/lnf_g/lnf_b + mask/tokens/targets
        assert_eq!(grouped.targets.len(), 16 + 4 + 3);
    }

    #[test]
    fn step_tile_propagates_and_counts_decisions() {
        let (program, device) = env_for(2, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let mut ep = env.reset();
        let acts = env.legal_actions(&ep);
        assert!(acts.len() > 10);
        // find the wq target and tile dim 1
        let ti = env
            .targets
            .iter()
            .position(|t| t.key.ends_with("attn/wq"))
            .unwrap();
        env.step(&mut ep, EnvAction::Tile { target: ti as u32, dim: 1, axis: 0 });
        assert_eq!(ep.decisions, 1);
        // cross-layer tying: BOTH layers' wq tiled
        let tiled_wqs = (0..program.func.num_args())
            .filter(|&i| {
                program.func.args[i].name.ends_with("wq") && ep.dm.is_tiled(i)
            })
            .count();
        assert_eq!(tiled_wqs, 2);
    }

    #[test]
    fn eval_memo_skips_repeat_terminal_states() {
        let (program, device) = env_for(1, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let mut memo = EvalMemo::new();

        // Two episodes that stop immediately share a terminal state.
        let mut ep1 = env.reset();
        env.step(&mut ep1, EnvAction::Stop);
        let mut ep2 = env.reset();
        env.step(&mut ep2, EnvAction::Stop);
        assert_eq!(env.state_fingerprint(&ep1), env.state_fingerprint(&ep2));

        let e1 = env.evaluate_episode_memo(&mut ep1, &mut memo);
        let e2 = env.evaluate_episode_memo(&mut ep2, &mut memo);
        assert_eq!(memo.lookups, 2);
        assert_eq!(memo.hits, 1);
        assert_eq!(memo.len(), 1);
        assert_eq!(e1.cost, e2.cost);
        // The memoized answer matches a fresh evaluation exactly.
        let fresh = env.evaluate_episode(&ep2);
        assert_eq!(e2.cost, fresh.cost);
        assert_eq!(e2.collectives, fresh.collectives);

        // A different terminal state is a different key.
        let mut ep3 = env.reset();
        let acts = env.legal_actions(&ep3);
        let tile = acts
            .iter()
            .find(|a| matches!(a, EnvAction::Tile { .. }))
            .copied()
            .expect("some tile action must be legal");
        env.step(&mut ep3, tile);
        env.step(&mut ep3, EnvAction::Stop);
        assert_ne!(env.state_fingerprint(&ep3), env.state_fingerprint(&ep1));
        let _ = env.evaluate_episode_memo(&mut ep3, &mut memo);
        assert_eq!(memo.hits, 1);
        assert_eq!(memo.len(), 2);
    }

    #[test]
    fn consecutive_infer_rest_is_not_offered() {
        let (program, device) = env_for(1, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let mut ep = env.reset();
        assert!(env.legal_actions(&ep).contains(&EnvAction::InferRest));
        env.step(&mut ep, EnvAction::InferRest);
        let acts = env.legal_actions(&ep);
        assert!(
            !acts.contains(&EnvAction::InferRest),
            "a repeated infer-rest is a no-op and must not burn a decision"
        );
        assert!(acts.contains(&EnvAction::Stop));
        // A tile decision re-arms it.
        if let Some(tile) = acts.iter().find(|a| matches!(a, EnvAction::Tile { .. })) {
            env.step(&mut ep, *tile);
            assert!(env.legal_actions(&ep).contains(&EnvAction::InferRest));
        }
    }

    #[test]
    fn legal_actions_into_matches_allocating_form_and_reuses_buffer() {
        let (program, device) = env_for(2, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let mut ep = env.reset();
        let mut buf = Vec::new();
        for _ in 0..4 {
            env.legal_actions_into(&ep, &mut buf);
            assert_eq!(buf, env.legal_actions(&ep));
            if buf.is_empty() {
                break;
            }
            let a = buf[0];
            env.step(&mut ep, a);
        }
    }

    #[test]
    fn eval_memo_cap_evicts_lru_half_deterministically() {
        let (program, device) = env_for(1, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        // Distinct terminal states: episodes with 0..n different first
        // tile actions.
        let mut eps = Vec::new();
        let base = env.reset();
        let acts: Vec<EnvAction> = env
            .legal_actions(&base)
            .into_iter()
            .filter(|a| matches!(a, EnvAction::Tile { .. }))
            .collect();
        assert!(acts.len() >= 6, "need enough distinct actions: {}", acts.len());
        for &a in acts.iter().take(6) {
            let mut ep = env.reset();
            env.step(&mut ep, a);
            env.step(&mut ep, EnvAction::Stop);
            eps.push(ep);
        }
        let mut memo = EvalMemo::with_cap(4);
        for ep in &mut eps {
            let _ = env.evaluate_episode_memo(ep, &mut memo);
        }
        assert!(memo.len() <= 4, "cap must bound the memo: {}", memo.len());
        assert!(memo.evictions > 0);
        // The most recent entry survived eviction and still hits.
        let hits_before = memo.hits;
        let _ = env.evaluate_episode_memo(&mut eps[5], &mut memo);
        assert_eq!(memo.hits, hits_before + 1);
        // Determinism: an identical second run sees identical counters.
        let mut memo2 = EvalMemo::with_cap(4);
        for ep in &mut eps {
            let _ = env.evaluate_episode_memo(ep, &mut memo2);
        }
        assert_eq!(memo2.len(), memo.len(), "eviction must be deterministic");
        assert_eq!(memo2.evictions, memo.evictions);
    }

    #[test]
    fn cut_moves_respect_neighbours_and_enter_the_fingerprint() {
        let (program, device) = env_for(2, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let mut env = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let cuts = crate::pipeline::balanced_cuts(&program.func, 4);
        env.set_pipeline(PipelineSpec { axis: 0, microbatches: 8, cuts: cuts.clone() });
        let mut ep = env.reset();
        assert_eq!(ep.cuts, cuts, "episodes start from the seed cuts");
        let f0 = env.state_fingerprint(&ep);
        let acts = env.legal_actions(&ep);
        let cut_move = acts
            .iter()
            .find(|a| matches!(a, EnvAction::CutMove { .. }))
            .copied()
            .expect("cut moves must be offered alongside tile actions");
        assert!(acts.iter().any(|a| matches!(a, EnvAction::Tile { .. })));
        env.step(&mut ep, cut_move);
        assert_eq!(ep.decisions, 1);
        assert_ne!(ep.cuts, cuts);
        for w in ep.cuts.windows(2) {
            assert!(w[0] < w[1], "cuts stay strictly increasing: {:?}", ep.cuts);
        }
        assert!((*ep.cuts.last().unwrap() as usize) < program.func.num_nodes());
        assert_ne!(env.state_fingerprint(&ep), f0, "cut positions are episode identity");
        // Pipelined evaluation flows through all three paths identically.
        let full = env.evaluate_episode(&ep);
        assert!(full.pipeline.is_some());
        let ledgered = env.evaluate_episode_ledger(&mut ep);
        assert_eq!(ledgered, full);
        let mut memo = EvalMemo::new();
        let memoed = env.evaluate_episode_memo(&mut ep, &mut memo);
        assert_eq!(memoed.cost.to_bits(), full.cost.to_bits());
    }

    #[test]
    fn stop_ends_episode_and_reward_is_normalised() {
        let (program, device) = env_for(1, SearchOptions::default());
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            device,
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let mut ep = env.reset();
        env.step(&mut ep, EnvAction::Stop);
        assert!(ep.done);
        assert!(env.legal_actions(&ep).is_empty());
        let eval = env.evaluate_episode(&ep);
        let r = env.reward(&eval);
        assert!((-1.0..=1.0).contains(&r));
    }
}
