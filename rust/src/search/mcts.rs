//! Monte Carlo Tree Search with UCT (paper §2.3: "We implemented Monte
//! Carlo Tree Search (MCTS) with upper confidence bound for trees
//! (UCT)") over the rewrite environment's action space.
//!
//! One *episode* = one tree walk (selection → expansion → random rollout
//! → backprop). The search returns the best terminal solution seen across
//! all episodes, which is what Figures 6–9 score.
//!
//! The searcher is persistent: [`Mcts::run_episodes`] can be called
//! repeatedly and the tree, RNG stream, and evaluation memo carry over —
//! this is what lets the service executor run episodes in rounds and
//! steal budget between trees (DESIGN.md §9) without touching the
//! statistics. The per-episode loop is allocation-free in the steady
//! state: the scratch episode is reset with buffer-reusing `clone_from`,
//! the selection path and rollout action list are reused vectors, and
//! the best solution is kept in place (cloned into only on strict
//! improvement).

use super::env::{Episode, EnvAction, EvalMemo, RewriteEnv};
use crate::cost::composite::Evaluation;
use crate::partir::actions::DecisionState;
use crate::util::rng::Rng;

struct Node {
    visits: u32,
    total_reward: f64,
    /// (action, child node id) — children created on expansion.
    children: Vec<(EnvAction, u32)>,
    /// Actions not yet expanded, shuffled at creation.
    untried: Vec<EnvAction>,
    terminal: bool,
}

/// Best solution found by a search run.
#[derive(Clone)]
pub struct SearchResult {
    pub best_state: DecisionState,
    /// Stage-cut boundaries of the best episode (empty unless the env
    /// has a pipeline context; see `RewriteEnv::set_pipeline`).
    pub best_cuts: Vec<u32>,
    pub best_eval: Evaluation,
    pub best_reward: f64,
    /// Episode index (1-based) at which the best solution was found.
    pub episodes_to_best: usize,
    pub episodes_run: usize,
    /// Terminal-state evaluations requested during the run.
    pub eval_lookups: usize,
    /// Evaluations served from the per-run memo (cost pipeline skipped).
    pub eval_memo_hits: usize,
    /// Memo misses answered by the incremental cost ledger.
    pub ledger_refreshes: usize,
    /// Node cost terms served from the ledger across those refreshes
    /// (the work the full pipeline would have redone).
    pub ledger_nodes_reused: usize,
    /// Node cost terms the ledger had to recompute (the dirty frontier).
    pub ledger_nodes_recomputed: usize,
}

/// MCTS hyperparameters.
#[derive(Debug, Clone)]
pub struct MctsConfig {
    pub exploration: f64,
    /// Probability the random rollout stops at each step.
    pub rollout_stop_prob: f64,
}

impl Default for MctsConfig {
    fn default() -> Self {
        MctsConfig { exploration: 1.2, rollout_stop_prob: 0.2 }
    }
}

/// Kept-in-place best solution (cloned into, not reallocated).
struct Best {
    state: DecisionState,
    cuts: Vec<u32>,
    eval: Evaluation,
    reward: f64,
    episode: usize,
}

pub struct Mcts<'e, 'p> {
    env: &'e RewriteEnv<'p>,
    cfg: MctsConfig,
    nodes: Vec<Node>,
    rng: Rng,
    memo: EvalMemo,
    root: u32,
    /// The root episode, built once — every episode resets from it with
    /// a buffer-reusing copy instead of a fresh `env.reset()`.
    root_ep: Episode,
    /// Scratch episode reused across the whole run.
    ep: Episode,
    /// Scratch selection path and rollout action list.
    path: Vec<u32>,
    acts: Vec<EnvAction>,
    episodes_run: usize,
    best: Option<Best>,
}

/// Create a node for `ep`'s state (free function so callers can hold
/// disjoint borrows of the searcher's fields).
fn push_node(nodes: &mut Vec<Node>, env: &RewriteEnv, ep: &Episode, rng: &mut Rng) -> u32 {
    let mut untried = env.legal_actions(ep);
    rng.shuffle(&mut untried);
    let terminal = untried.is_empty();
    nodes.push(Node { visits: 0, total_reward: 0.0, children: Vec::new(), untried, terminal });
    (nodes.len() - 1) as u32
}

impl<'e, 'p> Mcts<'e, 'p> {
    pub fn new(env: &'e RewriteEnv<'p>, cfg: MctsConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut nodes = Vec::with_capacity(1024);
        let root_ep = env.reset();
        let root = push_node(&mut nodes, env, &root_ep, &mut rng);
        let mut ep = root_ep.clone();
        // The scratch episode carries the run's cost ledger: memo misses
        // evaluate incrementally (O(changed nodes)) instead of
        // re-lowering the whole program. Bit-identical results, so this
        // cannot change which plan a seed produces.
        env.attach_ledger(&mut ep);
        Mcts {
            env,
            cfg,
            nodes,
            rng,
            memo: EvalMemo::new(),
            root,
            root_ep,
            ep,
            path: Vec::with_capacity(32),
            acts: Vec::new(),
            episodes_run: 0,
            best: None,
        }
    }

    fn ucb_select(&self, id: u32) -> Option<(EnvAction, u32)> {
        let n = &self.nodes[id as usize];
        if n.children.is_empty() {
            return None;
        }
        let ln_n = (n.visits.max(1) as f64).ln();
        let mut best = None;
        let mut best_score = f64::NEG_INFINITY;
        for &(a, cid) in &n.children {
            let c = &self.nodes[cid as usize];
            let mean = if c.visits == 0 { 0.0 } else { c.total_reward / c.visits as f64 };
            let score = mean + self.cfg.exploration * (ln_n / c.visits.max(1) as f64).sqrt();
            if score > best_score {
                best_score = score;
                best = Some((a, cid));
            }
        }
        best
    }

    /// Run `n` more episodes, continuing the existing tree and streams.
    pub fn run_episodes(&mut self, n: usize) {
        for _ in 0..n {
            self.episodes_run += 1;
            self.ep.clone_from(&self.root_ep);
            self.path.clear();
            self.path.push(self.root);
            let mut node = self.root;

            // Selection: descend while fully expanded.
            loop {
                let nd = &self.nodes[node as usize];
                if nd.terminal || !nd.untried.is_empty() {
                    break;
                }
                match self.ucb_select(node) {
                    Some((a, cid)) => {
                        self.env.step(&mut self.ep, a);
                        node = cid;
                        self.path.push(node);
                    }
                    None => break,
                }
            }

            // Expansion: try one untried action.
            if !self.nodes[node as usize].terminal {
                if let Some(a) = self.nodes[node as usize].untried.pop() {
                    self.env.step(&mut self.ep, a);
                    let child = push_node(&mut self.nodes, self.env, &self.ep, &mut self.rng);
                    self.nodes[node as usize].children.push((a, child));
                    node = child;
                    self.path.push(node);
                }
            }

            // Rollout: random policy to terminal, legality filtered into
            // the reused scratch buffer.
            while !self.ep.done {
                self.env.legal_actions_into(&self.ep, &mut self.acts);
                if self.acts.is_empty() {
                    break;
                }
                if self.rng.gen_f64() < self.cfg.rollout_stop_prob {
                    self.env.step(&mut self.ep, EnvAction::Stop);
                    break;
                }
                let a = *self.rng.choose(&self.acts);
                self.env.step(&mut self.ep, a);
            }

            // Evaluate + backprop. Revisited terminal states hit the
            // memo; fresh ones refresh the episode's cost ledger — the
            // full lower + liveness + roofline pipeline runs for neither.
            let eval = self.env.evaluate_episode_memo(&mut self.ep, &mut self.memo);
            let reward = self.env.reward(&eval);
            for &nid in &self.path {
                let nd = &mut self.nodes[nid as usize];
                nd.visits += 1;
                nd.total_reward += reward;
            }

            // Cheap pre-check first; clone the state only on strict
            // improvement, into the existing buffers.
            let improved = match &self.best {
                None => true,
                Some(b) => reward > b.reward,
            };
            if improved {
                let episode = self.episodes_run;
                match self.best.take() {
                    Some(mut b) => {
                        b.state.clone_from(&self.ep.state);
                        b.cuts.clone_from(&self.ep.cuts);
                        b.eval = eval;
                        b.reward = reward;
                        b.episode = episode;
                        self.best = Some(b);
                    }
                    None => {
                        self.best = Some(Best {
                            state: self.ep.state.clone(),
                            cuts: self.ep.cuts.clone(),
                            eval,
                            reward,
                            episode,
                        });
                    }
                }
            }
        }
    }

    /// Episodes run so far across all `run_episodes` calls.
    pub fn episodes_run(&self) -> usize {
        self.episodes_run
    }

    /// Best reward so far (`-inf` before the first episode).
    pub fn best_reward(&self) -> f64 {
        self.best.as_ref().map(|b| b.reward).unwrap_or(f64::NEG_INFINITY)
    }

    /// Normalised entropy of the root's child visit counts — the tree's
    /// "temperature". 1.0 = visits spread uniformly (still exploring or
    /// nothing to distinguish), → 0.0 = visits concentrated on one child
    /// (converged). The executor's stall detector watches this signal
    /// *stop moving* between rounds (DESIGN.md §9): a tree whose
    /// temperature has flattened is either converged or flat, and in
    /// both cases its marginal episodes teach nothing.
    pub fn root_visit_entropy(&self) -> f64 {
        let root = &self.nodes[self.root as usize];
        visit_entropy_of(root.children.iter().map(|&(_, cid)| self.nodes[cid as usize].visits))
    }

    /// Evaluation-memo `(lookups, hits)` so far — a cheap counter read
    /// for the executor's round-barrier telemetry samples, where the
    /// clone-heavy [`Mcts::result`] would be wasteful.
    pub fn memo_counters(&self) -> (usize, usize) {
        (self.memo.lookups, self.memo.hits)
    }

    /// Ledger `(refreshes, nodes_reused, nodes_recomputed)` so far
    /// (zeros when no ledger is attached). Same telemetry use as
    /// [`Mcts::memo_counters`].
    pub fn ledger_counters(&self) -> (usize, usize, usize) {
        match self.ep.ledger.as_ref() {
            Some(l) => (l.refreshes, l.nodes_reused, l.nodes_recomputed),
            None => (0, 0, 0),
        }
    }

    /// Snapshot the best solution found so far, or `None` when no
    /// episode has completed (a deadline hit before the first round, or
    /// a tree poisoned by a worker panic mid-episode). The executor
    /// falls back to a pre-tactics + InferRest plan in that case
    /// (DESIGN.md §14) instead of panicking here.
    pub fn result_opt(&self) -> Option<SearchResult> {
        self.best.as_ref()?;
        Some(self.result())
    }

    /// Snapshot the best solution found so far.
    pub fn result(&self) -> SearchResult {
        let b = self.best.as_ref().expect("budget must be >= 1");
        let ledger = self.ep.ledger.as_ref();
        let (ledger_refreshes, ledger_nodes_reused, ledger_nodes_recomputed) =
            ledger.map(|l| (l.refreshes, l.nodes_reused, l.nodes_recomputed)).unwrap_or((0, 0, 0));
        SearchResult {
            best_state: b.state.clone(),
            best_cuts: b.cuts.clone(),
            best_eval: b.eval.clone(),
            best_reward: b.reward,
            episodes_to_best: b.episode,
            episodes_run: self.episodes_run,
            eval_lookups: self.memo.lookups,
            eval_memo_hits: self.memo.hits,
            ledger_refreshes,
            ledger_nodes_reused,
            ledger_nodes_recomputed,
        }
    }
}

/// Normalised Shannon entropy of a visit-count distribution: `H / ln n`
/// over the positive counts, 0.0 for fewer than two children or no
/// visits. Deterministic for deterministic visit counts, which keeps the
/// executor's entropy-based stall decisions reproducible.
pub fn visit_entropy_of(visits: impl Iterator<Item = u32>) -> f64 {
    let counts: Vec<u32> = visits.collect();
    let total: u64 = counts.iter().map(|&v| v as u64).sum();
    if counts.len() < 2 || total == 0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &v in &counts {
        if v > 0 {
            let p = v as f64 / total as f64;
            h -= p * p.ln();
        }
    }
    h / (counts.len() as f64).ln()
}

/// Convenience wrapper: one full search.
pub fn search(env: &RewriteEnv, budget: usize, seed: u64, cfg: MctsConfig) -> SearchResult {
    let mut m = Mcts::new(env, cfg, seed);
    m.run_episodes(budget);
    m.result()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::composite::CostWeights;
    use crate::models::transformer::{build_transformer, TransformerConfig};
    use crate::partir::mesh::Mesh;
    use crate::partir::program::PartirProgram;
    use crate::search::env::SearchOptions;
    use crate::sim::device::Device;

    fn mlp_env_program() -> PartirProgram {
        // A 2-layer MLP: Megatron-style col/row sharding is the optimum.
        let m = crate::models::mlp::build_mlp(&crate::models::mlp::MlpConfig {
            batch: 8,
            dims: vec![64, 256, 64],
            training: false,
        });
        PartirProgram::new(m.func, Mesh::new(&[("model", 4)]))
    }

    #[test]
    fn mcts_improves_over_random_baseline() {
        let program = mlp_env_program();
        let dm0 = crate::partir::dist::DistMap::new(&program.func, &program.mesh);
        let w = CostWeights::default();
        let probe = crate::cost::composite::evaluate(&program, &dm0, &Device::tpu_v3(), &w);
        // memory pressure so sharding is required
        let dev = Device { hbm_bytes: probe.memory.peak_bytes / 2, ..Device::tpu_v3() };
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(&program, dev, w, SearchOptions::default(), &wl);
        let res = search(&env, 300, 42, MctsConfig::default());
        assert!(res.best_reward > 0.0, "search should beat replication");
        assert!(res.best_eval.fits_memory);
        assert!(res.episodes_to_best <= 300);
    }

    #[test]
    fn deterministic_given_seed() {
        let program = mlp_env_program();
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            Device::tpu_v3(),
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let a = search(&env, 50, 7, MctsConfig::default());
        let b = search(&env, 50, 7, MctsConfig::default());
        assert_eq!(a.best_reward, b.best_reward);
        assert_eq!(a.episodes_to_best, b.episodes_to_best);
        assert_eq!(a.eval_memo_hits, b.eval_memo_hits);
    }

    #[test]
    fn chunked_runs_equal_one_shot_runs() {
        // The round-based executor depends on this: running 50 episodes
        // as 5 x 10 continues the same tree/RNG/memo and lands on the
        // same best solution as one 50-episode call.
        let program = mlp_env_program();
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            Device::tpu_v3(),
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let one_shot = search(&env, 50, 9, MctsConfig::default());
        let mut m = Mcts::new(&env, MctsConfig::default(), 9);
        for _ in 0..5 {
            m.run_episodes(10);
        }
        let chunked = m.result();
        assert_eq!(one_shot.best_reward, chunked.best_reward);
        assert_eq!(one_shot.episodes_to_best, chunked.episodes_to_best);
        assert_eq!(one_shot.eval_lookups, chunked.eval_lookups);
        assert_eq!(one_shot.eval_memo_hits, chunked.eval_memo_hits);
        assert_eq!(one_shot.ledger_refreshes, chunked.ledger_refreshes);
        assert_eq!(one_shot.ledger_nodes_reused, chunked.ledger_nodes_reused);
        assert_eq!(one_shot.ledger_nodes_recomputed, chunked.ledger_nodes_recomputed);
        assert_eq!(
            one_shot.best_state.actions,
            chunked.best_state.actions,
            "chunked episodes must replay the identical action stream"
        );
    }

    #[test]
    fn memo_counts_repeat_terminal_states_without_changing_results() {
        let program = mlp_env_program();
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            Device::tpu_v3(),
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let res = search(&env, 300, 11, MctsConfig::default());
        // Every episode routes one evaluation through the memo…
        assert_eq!(res.eval_lookups, 300);
        // …and random rollouts revisit identical terminal states often
        // enough that some evaluations are served from it. (The env-level
        // test proves a memoized answer equals a fresh evaluation.)
        assert!(res.eval_memo_hits > 0, "expected memo hits in 300 episodes");
        assert!(res.eval_memo_hits < res.eval_lookups);
        // Every memo miss is a ledger refresh — the full pipeline never
        // runs inside the episode loop — and the refreshes reuse cached
        // node terms.
        assert_eq!(res.ledger_refreshes, res.eval_lookups - res.eval_memo_hits);
        assert!(res.ledger_nodes_reused > 0, "ledger must reuse some node terms");
    }

    #[test]
    fn visit_entropy_is_normalised_and_deterministic() {
        // Degenerate inputs pin the boundary conventions.
        assert_eq!(visit_entropy_of(std::iter::empty()), 0.0);
        assert_eq!(visit_entropy_of([7u32].into_iter()), 0.0);
        assert_eq!(visit_entropy_of([0, 0].into_iter()), 0.0);
        // Uniform visits = maximum temperature, exactly 1.0.
        let uniform = visit_entropy_of([5u32, 5, 5, 5].into_iter());
        assert!((uniform - 1.0).abs() < 1e-12, "uniform entropy {uniform}");
        // Concentration cools the tree monotonically.
        let mild = visit_entropy_of([8u32, 4, 2, 2].into_iter());
        let sharp = visit_entropy_of([1000u32, 1, 1, 1].into_iter());
        assert!(mild < uniform && sharp < mild, "{sharp} < {mild} < {uniform}");
        assert!(sharp > 0.0 && sharp < 0.05);
        // Zero-visit children count toward n (they are still candidate
        // arms), so a one-hot distribution over many arms is cold.
        assert!(visit_entropy_of([10u32, 0, 0, 0].into_iter()) == 0.0);
        // Deterministic: same counts, same bits.
        assert_eq!(
            visit_entropy_of([8u32, 4, 2, 2].into_iter()).to_bits(),
            visit_entropy_of([8u32, 4, 2, 2].into_iter()).to_bits()
        );
    }

    #[test]
    fn root_visit_entropy_reflects_the_tree() {
        let program = mlp_env_program();
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            Device::tpu_v3(),
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let m = Mcts::new(&env, MctsConfig::default(), 5);
        assert_eq!(m.root_visit_entropy(), 0.0, "an unexpanded root has no temperature");
        let mut m = m;
        m.run_episodes(200);
        let h = m.root_visit_entropy();
        assert!((0.0..=1.0).contains(&h), "entropy must be normalised: {h}");
        assert!(h > 0.0, "200 episodes must expand and visit several children");
        // Reproducible for a fixed seed (the executor's stall decisions
        // depend on it).
        let mut m2 = Mcts::new(&env, MctsConfig::default(), 5);
        m2.run_episodes(200);
        assert_eq!(h.to_bits(), m2.root_visit_entropy().to_bits());
    }

    #[test]
    fn finds_megatron_on_tiny_transformer_with_tying() {
        use crate::models::megatron;
        use crate::partir::mesh::AxisId;
        let model = build_transformer(&TransformerConfig::tiny(2));
        let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
        let w = CostWeights::default();
        let reference = megatron::reference_evaluation(
            &program,
            &model,
            AxisId(0),
            &Device::tpu_v3(),
            &w,
        );
        let dev = Device {
            hbm_bytes: (reference.memory.peak_bytes as f64 * 1.3) as i64,
            ..Device::tpu_v3()
        };
        let reference = megatron::reference_evaluation(&program, &model, AxisId(0), &dev, &w);
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(&program, dev, w, SearchOptions::default(), &wl);
        // generous budget; success checked via the collective detector
        let res = search(&env, 2000, 3, MctsConfig::default());
        let verdict = megatron::check(&res.best_eval, &reference);
        assert!(
            verdict.is_megatron || verdict.near_megatron,
            "expected (near-)Megatron: found={:?} ref={:?}",
            res.best_eval.collectives,
            reference.collectives
        );
    }
}
