//! Monte Carlo Tree Search with UCT (paper §2.3: "We implemented Monte
//! Carlo Tree Search (MCTS) with upper confidence bound for trees
//! (UCT)") over the rewrite environment's action space.
//!
//! One *episode* = one tree walk (selection → expansion → random rollout
//! → backprop). The search returns the best terminal solution seen across
//! all episodes, which is what Figures 6–9 score.

use super::env::{Episode, EnvAction, EvalMemo, RewriteEnv};
use crate::cost::composite::Evaluation;
use crate::partir::actions::DecisionState;
use crate::util::rng::Rng;

struct Node {
    visits: u32,
    total_reward: f64,
    /// (action, child node id) — children created on expansion.
    children: Vec<(EnvAction, u32)>,
    /// Actions not yet expanded, shuffled at creation.
    untried: Vec<EnvAction>,
    terminal: bool,
}

/// Best solution found by a search run.
#[derive(Clone)]
pub struct SearchResult {
    pub best_state: DecisionState,
    pub best_eval: Evaluation,
    pub best_reward: f64,
    /// Episode index (1-based) at which the best solution was found.
    pub episodes_to_best: usize,
    pub episodes_run: usize,
    /// Terminal-state evaluations requested during the run.
    pub eval_lookups: usize,
    /// Evaluations served from the per-run memo (cost pipeline skipped).
    pub eval_memo_hits: usize,
}

/// MCTS hyperparameters.
#[derive(Debug, Clone)]
pub struct MctsConfig {
    pub exploration: f64,
    /// Probability the random rollout stops at each step.
    pub rollout_stop_prob: f64,
}

impl Default for MctsConfig {
    fn default() -> Self {
        MctsConfig { exploration: 1.2, rollout_stop_prob: 0.2 }
    }
}

pub struct Mcts<'e, 'p> {
    env: &'e RewriteEnv<'p>,
    cfg: MctsConfig,
    nodes: Vec<Node>,
}

impl<'e, 'p> Mcts<'e, 'p> {
    pub fn new(env: &'e RewriteEnv<'p>, cfg: MctsConfig) -> Self {
        Mcts { env, cfg, nodes: Vec::with_capacity(1024) }
    }

    fn make_node(&mut self, ep: &Episode, rng: &mut Rng) -> u32 {
        let mut untried = self.env.legal_actions(ep);
        rng.shuffle(&mut untried);
        let terminal = untried.is_empty();
        self.nodes.push(Node {
            visits: 0,
            total_reward: 0.0,
            children: Vec::new(),
            untried,
            terminal,
        });
        (self.nodes.len() - 1) as u32
    }

    fn ucb_select(&self, id: u32) -> Option<(EnvAction, u32)> {
        let n = &self.nodes[id as usize];
        if n.children.is_empty() {
            return None;
        }
        let ln_n = (n.visits.max(1) as f64).ln();
        let mut best = None;
        let mut best_score = f64::NEG_INFINITY;
        for &(a, cid) in &n.children {
            let c = &self.nodes[cid as usize];
            let mean = if c.visits == 0 { 0.0 } else { c.total_reward / c.visits as f64 };
            let score = mean + self.cfg.exploration * (ln_n / c.visits.max(1) as f64).sqrt();
            if score > best_score {
                best_score = score;
                best = Some((a, cid));
            }
        }
        best
    }

    /// Run `budget` episodes; return the best solution found.
    pub fn run(&mut self, budget: usize, seed: u64) -> SearchResult {
        let mut rng = Rng::new(seed);
        let mut memo = EvalMemo::new();
        let root_ep = self.env.reset();
        let root = self.make_node(&root_ep, &mut rng);

        let mut best: Option<SearchResult> = None;
        for episode in 1..=budget {
            let mut ep = self.env.reset();
            let mut path: Vec<u32> = vec![root];
            let mut node = root;

            // Selection: descend while fully expanded.
            loop {
                let n = &self.nodes[node as usize];
                if n.terminal || !n.untried.is_empty() {
                    break;
                }
                match self.ucb_select(node) {
                    Some((a, cid)) => {
                        self.env.step(&mut ep, a);
                        node = cid;
                        path.push(node);
                    }
                    None => break,
                }
            }

            // Expansion: try one untried action.
            if !self.nodes[node as usize].terminal {
                if let Some(a) = self.nodes[node as usize].untried.pop() {
                    self.env.step(&mut ep, a);
                    let child = self.make_node(&ep, &mut rng);
                    self.nodes[node as usize].children.push((a, child));
                    node = child;
                    path.push(node);
                }
            }

            // Rollout: random policy to terminal.
            while !ep.done {
                let acts = self.env.legal_actions(&ep);
                if acts.is_empty() {
                    break;
                }
                if rng.gen_f64() < self.cfg.rollout_stop_prob {
                    self.env.step(&mut ep, EnvAction::Stop);
                    break;
                }
                let a = *rng.choose(&acts);
                self.env.step(&mut ep, a);
            }

            // Evaluate + backprop. Revisited terminal states hit the memo
            // and skip the lower + liveness + roofline pipeline.
            let eval = self.env.evaluate_episode_memo(&ep, &mut memo);
            let reward = self.env.reward(&eval);
            for &nid in &path {
                let n = &mut self.nodes[nid as usize];
                n.visits += 1;
                n.total_reward += reward;
            }

            let better = match &best {
                None => true,
                Some(b) => reward > b.best_reward,
            };
            if better {
                best = Some(SearchResult {
                    best_state: ep.state.clone(),
                    best_eval: eval,
                    best_reward: reward,
                    episodes_to_best: episode,
                    episodes_run: episode,
                    eval_lookups: 0,
                    eval_memo_hits: 0,
                });
            }
        }
        let mut r = best.expect("budget must be >= 1");
        r.episodes_run = budget;
        r.eval_lookups = memo.lookups;
        r.eval_memo_hits = memo.hits;
        r
    }
}

/// Convenience wrapper: one full search.
pub fn search(env: &RewriteEnv, budget: usize, seed: u64, cfg: MctsConfig) -> SearchResult {
    Mcts::new(env, cfg).run(budget, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::composite::CostWeights;
    use crate::models::transformer::{build_transformer, TransformerConfig};
    use crate::partir::mesh::Mesh;
    use crate::partir::program::PartirProgram;
    use crate::search::env::SearchOptions;
    use crate::sim::device::Device;

    fn mlp_env_program() -> PartirProgram {
        // A 2-layer MLP: Megatron-style col/row sharding is the optimum.
        let m = crate::models::mlp::build_mlp(&crate::models::mlp::MlpConfig {
            batch: 8,
            dims: vec![64, 256, 64],
            training: false,
        });
        PartirProgram::new(m.func, Mesh::new(&[("model", 4)]))
    }

    #[test]
    fn mcts_improves_over_random_baseline() {
        let program = mlp_env_program();
        let dm0 = crate::partir::dist::DistMap::new(&program.func, &program.mesh);
        let w = CostWeights::default();
        let probe = crate::cost::composite::evaluate(&program, &dm0, &Device::tpu_v3(), &w);
        // memory pressure so sharding is required
        let dev = Device { hbm_bytes: probe.memory.peak_bytes / 2, ..Device::tpu_v3() };
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(&program, dev, w, SearchOptions::default(), &wl);
        let res = search(&env, 300, 42, MctsConfig::default());
        assert!(res.best_reward > 0.0, "search should beat replication");
        assert!(res.best_eval.fits_memory);
        assert!(res.episodes_to_best <= 300);
    }

    #[test]
    fn deterministic_given_seed() {
        let program = mlp_env_program();
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            Device::tpu_v3(),
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let a = search(&env, 50, 7, MctsConfig::default());
        let b = search(&env, 50, 7, MctsConfig::default());
        assert_eq!(a.best_reward, b.best_reward);
        assert_eq!(a.episodes_to_best, b.episodes_to_best);
        assert_eq!(a.eval_memo_hits, b.eval_memo_hits);
    }

    #[test]
    fn memo_counts_repeat_terminal_states_without_changing_results() {
        let program = mlp_env_program();
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(
            &program,
            Device::tpu_v3(),
            CostWeights::default(),
            SearchOptions::default(),
            &wl,
        );
        let res = search(&env, 300, 11, MctsConfig::default());
        // Every episode routes one evaluation through the memo…
        assert_eq!(res.eval_lookups, 300);
        // …and random rollouts revisit identical terminal states often
        // enough that some evaluations are served from it. (The env-level
        // test proves a memoized answer equals a fresh evaluation.)
        assert!(res.eval_memo_hits > 0, "expected memo hits in 300 episodes");
        assert!(res.eval_memo_hits < res.eval_lookups);
    }

    #[test]
    fn finds_megatron_on_tiny_transformer_with_tying() {
        use crate::models::megatron;
        use crate::partir::mesh::AxisId;
        let model = build_transformer(&TransformerConfig::tiny(2));
        let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
        let w = CostWeights::default();
        let reference = megatron::reference_evaluation(
            &program,
            &model,
            AxisId(0),
            &Device::tpu_v3(),
            &w,
        );
        let dev = Device {
            hbm_bytes: (reference.memory.peak_bytes as f64 * 1.3) as i64,
            ..Device::tpu_v3()
        };
        let reference = megatron::reference_evaluation(&program, &model, AxisId(0), &dev, &w);
        let wl = RewriteEnv::default_worklist(&program);
        let env = RewriteEnv::new(&program, dev, w, SearchOptions::default(), &wl);
        // generous budget; success checked via the collective detector
        let res = search(&env, 2000, 3, MctsConfig::default());
        let verdict = megatron::check(&res.best_eval, &reference);
        assert!(
            verdict.is_megatron || verdict.near_megatron,
            "expected (near-)Megatron: found={:?} ref={:?}",
            res.best_eval.collectives,
            reference.collectives
        );
    }
}
