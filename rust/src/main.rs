//! `automap` CLI — leader entrypoint for the reproduction.
//!
//! Subcommands:
//!   stats        model-scale statistics vs. the paper's setup (§3)
//!   gen-dataset  generate the ranker training set (best-strategy labels)
//!   partition    run a Session tactic pipeline on a model (or a textual
//!                program via --program f.pir) and print the partition
//!                plan (supports --pin / --shard constraints)
//!   parse        parse a textual-IR file (DESIGN.md §10): verify it and
//!                check the print/parse round-trip, exit non-zero on any
//!                mismatch (the corpus CI wall runs this)
//!   print        print a built-in model in the textual IR form
//!   serve        read JSONL partition requests from stdin, answer on
//!                stdout through the plan service (--stdin-jsonl)
//!   batch        answer a JSONL request file through the plan service
//!   encode       emit a program (.pir or --model) or a plan JSON in the
//!                versioned pallas-bin binary form (DESIGN.md §13)
//!   decode       decode a .pbp file back to textual IR / plan JSON,
//!                optionally re-encoding to check byte-exactness (--check)
//!   explain      render a plan JSON (or a batch responses.jsonl) as a
//!                human-readable partitioning narrative (degradation
//!                annotations included)
//!   sync         run one replica anti-entropy round: publish this
//!                replica's plan-log snapshot into --sync-dir and pull
//!                missing plans from peer snapshots (DESIGN.md §15)
//!   fig6 / fig7 / fig8 / fig9   regenerate the paper's figures
//!   all-figures  run every figure harness
//!
//! Common flags: --layers N --budgets a,b,c --attempts N --seed S
//!               --config path.json --out-dir results
//! Partition flags: --pin axis[,axis]  --shard name:dim:axis[,...]
//!                  --program file.pir
//! Service flags:   --pool N --cache-mb N --cache-dir .plan-cache
//!                  --out responses.jsonl --deadline-ms N --max-pending N
//!                  (PALLAS_FAILPOINTS=name=prob[@seed] arms fault injection)
//! Observability:   --trace out.json (Perfetto/chrome://tracing format)
//!                  --metrics-out metrics.json (counter/histogram snapshot)

use automap::coordinator::config as cfgfile;
use automap::coordinator::figures::{self, FigureSetup};
use automap::ir::{parse_func, print_func, Func};
use automap::learner::ranker::TOP_K;
use automap::models::transformer::TransformerConfig;
use automap::obs::recorder::recorder;
use automap::partir::mesh::Mesh;
use automap::search::mcts::MctsConfig;
use automap::service::{run_batch, serve_jsonl, PartitionRequest, PlanService, ServiceConfig};
use automap::session::{RankerSpec, Session, ShardingConstraint, Tactic};
use automap::util::cli::Args;

const VALUE_FLAGS: &[&str] = &[
    "layers", "budgets", "attempts", "seed", "out", "out-dir", "count", "axis", "model",
    "budget", "filter", "ranker", "config", "d-model", "mesh", "pin", "shard", "pool",
    "cache-mb", "cache-dir", "program", "pipeline", "trace", "metrics-out", "deadline-ms",
    "max-pending", "sync-dir", "sync-interval", "replica",
];
const BOOL_FLAGS: &[&str] = &["paper", "grouping", "no-tying", "help", "stdin-jsonl", "check"];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        return;
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(&argv[1..], VALUE_FLAGS, BOOL_FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.get_bool("help") {
        usage();
        return;
    }
    let r = match cmd.as_str() {
        "stats" => cmd_stats(&args),
        "gen-dataset" => cmd_gen_dataset(&args),
        "partition" => cmd_partition(&args),
        "parse" => cmd_parse(&args),
        "print" => cmd_print(&args),
        "serve" => cmd_serve(&args),
        "batch" => cmd_batch(&args),
        "encode" => cmd_encode(&args),
        "decode" => cmd_decode(&args),
        "explain" => cmd_explain(&args),
        "sync" => cmd_sync(&args),
        "fig6" | "fig7" => figure_cmd(&args, |s, d| figures::fig6_fig7(s, d).map(|_| ())),
        "fig8" => figure_cmd(&args, |s, d| figures::fig8(s, d).map(|_| ())),
        "fig9" => figure_cmd(&args, |s, d| figures::fig9(s, d).map(|_| ())),
        "all-figures" => figure_cmd(&args, |s, d| {
            figures::fig6_fig7(s, d)?;
            figures::fig8(s, d)?;
            figures::fig9(s, d)?;
            Ok(())
        }),
        _ => {
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "automap — reproduction of 'Automap: Towards Ergonomic Automated Parallelism'\n\
         usage: automap <stats|gen-dataset|partition|parse|print|serve|batch|encode|decode|\n\
                         explain|sync|fig6|fig7|fig8|fig9|all-figures> [flags]\n\
         flags: --layers N --budgets a,b,c --attempts N --seed S --paper\n\
                --model mlp|transformer|graphnet --budget N --filter none|heuristic|learned\n\
                --mesh model=4[,batch=2] --ranker artifacts/ranker.hlo.txt\n\
                --config cfg.json --out-dir results --count N (gen-dataset)\n\
         partition constraints (paper Fig 5):\n\
                --pin axis[,axis]          mark mesh axes manual (excluded from search)\n\
                --shard name:dim:axis[,..] pre-shard arguments before search,\n\
                                           e.g. --shard x:0:batch,dense_0/w:1:model\n\
                --program file.pir         partition a textual-IR program instead\n\
                                           of a built-in model\n\
                --pipeline stages=K[,microbatches=M][,axis=N]\n\
                                           cut the program into K pipeline stages and\n\
                                           price them through the 1F1B schedule (DESIGN.md §11)\n\
         textual IR (DESIGN.md §10):\n\
                parse file.pir             parse + verify + round-trip check\n\
                print --model mlp [--out f.pir]   emit a built-in model as text\n\
         plan service (one JSON request per line; see README 'Serving partition plans'):\n\
                serve --stdin-jsonl [--pool N] [--cache-mb N] [--metrics-out m.json]\n\
                batch requests.jsonl [--pool N] [--cache-mb N] [--out responses.jsonl]\n\
                      [--trace trace.json] [--metrics-out m.json]\n\
                both: --cache-dir .plan-cache   persistent plan-cache tier under the LRU\n\
                      (append-only CRC-framed log; plans survive the process, DESIGN.md §13)\n\
         failure handling (DESIGN.md §14):\n\
                --deadline-ms N     default per-request deadline; a search that hits it\n\
                                    returns its best-so-far plan marked degraded:\"deadline\"\n\
                --max-pending N     serve admission bound: arrivals beyond it are shed\n\
                                    with a cached-or-fallback response (degraded:\"shed\")\n\
                PALLAS_FAILPOINTS=name=prob[@seed],...   deterministic fault injection\n\
                                    (worker.panic, disk.read_err, disk.write_err,\n\
                                    search.slow_round, sync.frame_corrupt, sync.conn_drop,\n\
                                    sync.partial_write)\n\
         replica sync (DESIGN.md §15):\n\
                sync --cache-dir .plan-cache --sync-dir /shared/sync [--replica NAME]\n\
                                    one anti-entropy round: canonicalize + publish the\n\
                                    local plan log, pull missing plans from peer snapshots\n\
                serve ... --sync-dir DIR [--sync-interval SECS] [--replica NAME]\n\
                                    background sync ticker while serving (0 = off)\n\
         binary interchange — pallas-bin (DESIGN.md §13):\n\
                encode file.pir|plan.json [--out f.pbp]     program text or plan JSON -> binary\n\
                encode --model mlp [--layers N] [--out f.pbp]\n\
                decode file.pbp [--out f] [--check]         binary -> textual IR / plan JSON;\n\
                                                            --check re-encodes and byte-compares\n\
         observability (DESIGN.md §12):\n\
                partition ... --trace trace.json   record a Perfetto-loadable trace\n\
                explain plan.json|responses.jsonl  narrate a plan: mesh, cost, shardings,\n\
                                                   the tactic timeline, and any degradation\n\
                                                   annotations (degraded/fallback/panics)"
    );
}

fn cmd_stats(args: &Args) -> anyhow::Result<()> {
    let cfg = if args.get_bool("paper") {
        TransformerConfig::paper()
    } else {
        TransformerConfig::tiny(args.get_usize("layers", 24)?)
    };
    let j = figures::stats(&cfg);
    if let Some(out) = args.get("out") {
        std::fs::write(out, j.pretty())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_gen_dataset(args: &Args) -> anyhow::Result<()> {
    let count = args.get_usize("count", 64)?;
    let seed = args.get_u64("seed", 7)?;
    let out = args.get_str("out", "artifacts/dataset.json");
    let t0 = std::time::Instant::now();
    println!("generating {count} labelled transformer variants (greedy best-strategy)...");
    let j = automap::learner::dataset::generate_dataset(count, seed, 4);
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, j.to_string())?;
    println!("wrote {out} in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// Build a built-in model's `Func` (shared by partition/print; same
/// name→model map as the service, via `models::build_by_name`).
fn build_model_func(model: &str, layers: usize) -> anyhow::Result<Func> {
    automap::models::build_by_name(model, layers)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model}' (want mlp|transformer|graphnet)"))
}

fn cmd_parse(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("parse needs a file.pir path"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let f = parse_func(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    // Round-trip wall: the printed form must re-parse to the same
    // function. `f` is already verified by parse_func.
    let printed = print_func(&f);
    let g = parse_func(&printed)
        .map_err(|e| anyhow::anyhow!("{path}: printed form failed to re-parse: {e}"))?;
    if g != f {
        anyhow::bail!("{path}: round-trip mismatch — parse(print(parse(text))) != parse(text)");
    }
    println!(
        "{path}: ok — func @{}: {} args, {} nodes, {} outputs, {} scopes",
        f.name,
        f.num_args(),
        f.num_nodes(),
        f.outputs.len(),
        f.scopes.len()
    );
    Ok(())
}

fn cmd_print(args: &Args) -> anyhow::Result<()> {
    let model = args.get_str("model", "transformer");
    // Same default depth as `partition`, so print → partition --program
    // reproduces exactly what partition --model would plan.
    let f = build_model_func(&model, args.get_usize("layers", 4)?)?;
    let text = print_func(&f);
    match args.get("out") {
        Some(p) => {
            std::fs::write(p, &text)?;
            println!("wrote {p}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// `encode [file.pir|plan.json] [--model m] [--out f.pbp]` — emit the
/// versioned pallas-bin form (DESIGN.md §13). The input is sniffed by
/// content: a leading `{` is a serialised `PartitionPlan`, anything
/// else parses as textual IR. `--model` (with `--layers`) encodes a
/// built-in model directly, no intermediate `.pir` file needed.
fn cmd_encode(args: &Args) -> anyhow::Result<()> {
    use automap::ir::binary;
    let (bytes, default_out) = match args.positional.first() {
        Some(path) => {
            if args.get("model").is_some() {
                anyhow::bail!("encode takes a file or --model, not both");
            }
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            let bytes = if text.trim_start().starts_with('{') {
                let doc = automap::util::json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                let plan = automap::session::PartitionPlan::from_json(&doc)
                    .map_err(|e| anyhow::anyhow!("{path}: {e:#}"))?;
                binary::encode_plan(&plan)
            } else {
                let f = parse_func(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                binary::encode_program(&f)
            };
            let out = std::path::Path::new(path).with_extension("pbp");
            (bytes, out.display().to_string())
        }
        None => {
            let model = args.get_str("model", "transformer");
            let f = build_model_func(&model, args.get_usize("layers", 4)?)?;
            (binary::encode_program(&f), format!("{model}.pbp"))
        }
    };
    let out = args.get_str("out", &default_out);
    std::fs::write(&out, &bytes).map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
    println!("wrote {out} ({} bytes)", bytes.len());
    Ok(())
}

/// `decode file.pbp [--out f] [--check]` — decode pallas-bin back to
/// the textual form (program -> textual IR, plan -> pretty plan JSON).
/// `--check` re-encodes the decoded value and byte-compares against the
/// input, proving `encode(decode(bytes)) == bytes` for this file.
fn cmd_decode(args: &Args) -> anyhow::Result<()> {
    use automap::ir::binary;
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("decode needs a file.pbp path"))?;
    let bytes = std::fs::read(path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let kind = binary::sniff_kind(&bytes);
    let (text, reencoded, what) = match kind {
        Some(binary::KIND_PROGRAM) => {
            let f = binary::decode_program(&bytes).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let text = print_func(&f);
            let re = binary::encode_program(&f);
            let what = format!(
                "program @{}: {} args, {} nodes, {} outputs",
                f.name,
                f.num_args(),
                f.num_nodes(),
                f.outputs.len()
            );
            (text, re, what)
        }
        Some(binary::KIND_PLAN) => {
            let plan = binary::decode_plan(&bytes).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let mut text = plan.to_json().pretty();
            text.push('\n');
            let re = binary::encode_plan(&plan);
            let what = format!("plan ({} decisions)", plan.decisions);
            (text, re, what)
        }
        _ => {
            // Not pallas-bin at all: decode_program produces the
            // precise header diagnostic (bad magic / truncation).
            let e = binary::decode_program(&bytes).unwrap_err();
            anyhow::bail!("{path}: {e}");
        }
    };
    if args.get_bool("check") {
        if reencoded != bytes {
            anyhow::bail!("{path}: re-encode mismatch — decode(bytes) did not round-trip");
        }
        eprintln!("{path}: check ok — re-encode is byte-identical ({} bytes)", bytes.len());
    }
    match args.get("out") {
        Some(p) => {
            std::fs::write(p, &text)?;
            println!("decoded {what}; wrote {p}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// `--trace out.json`: arm the global flight recorder before the work
/// runs. Returns the output path so the caller can dump afterwards.
fn arm_trace(args: &Args) -> Option<String> {
    let path = args.get("trace")?.to_string();
    recorder().clear();
    recorder().enable();
    Some(path)
}

/// Dump the recorded trace (chrome://tracing / Perfetto format) and
/// disarm the recorder.
fn write_trace(path: &str) -> anyhow::Result<()> {
    recorder().disable();
    std::fs::write(path, recorder().chrome_trace().to_string())?;
    let dropped = recorder().dropped_events();
    if dropped > 0 {
        eprintln!("trace: ring buffers overflowed, {dropped} oldest events dropped");
    }
    println!("wrote trace {path}");
    Ok(())
}

/// `--metrics-out m.json`: dump the process-wide metrics registry plus
/// per-request telemetry (DESIGN.md §12).
fn write_metrics(args: &Args) -> anyhow::Result<()> {
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, automap::obs::metrics_snapshot().pretty())?;
        println!("wrote metrics {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if !args.get_bool("stdin-jsonl") {
        anyhow::bail!("serve reads JSONL requests from stdin; pass --stdin-jsonl to confirm");
    }
    automap::util::failpoints::arm_from_env()?;
    let pool = args.get_usize("pool", 2)?;
    let max_pending = args.get_usize("max-pending", 0)?;
    let svc = PlanService::try_new(ServiceConfig {
        defaults: automap::service::JobDefaults {
            deadline_ms: args.get_u64("deadline-ms", 0)?,
            ..automap::service::JobDefaults::default()
        },
        cache_bytes: args.get_usize("cache-mb", 64)? << 20,
        persist_path: args.get("cache-dir").map(std::path::PathBuf::from),
        sync_dir: args.get("sync-dir").map(std::path::PathBuf::from),
        sync_interval_secs: args.get_u64("sync-interval", 0)?,
        replica: args.get("replica").map(str::to_string),
        ..ServiceConfig::default()
    })?;
    let stdout = std::sync::Mutex::new(std::io::stdout());
    let stdin = std::io::stdin();
    let summary = serve_jsonl(&svc, stdin.lock(), &stdout, pool, max_pending)?;
    eprintln!("serve: {}", summary.describe());
    write_metrics(args)?;
    Ok(())
}

fn cmd_batch(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("batch needs a requests.jsonl path"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let mut requests = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let req = PartitionRequest::parse_line(line)
            .map_err(|e| anyhow::anyhow!("{path}:{}: {e:#}", ln + 1))?;
        requests.push(req);
    }
    automap::util::failpoints::arm_from_env()?;
    let pool = args.get_usize("pool", 2)?;
    let svc = PlanService::try_new(ServiceConfig {
        defaults: automap::service::JobDefaults {
            deadline_ms: args.get_u64("deadline-ms", 0)?,
            ..automap::service::JobDefaults::default()
        },
        cache_bytes: args.get_usize("cache-mb", 64)? << 20,
        persist_path: args.get("cache-dir").map(std::path::PathBuf::from),
        ..ServiceConfig::default()
    })?;
    let trace = arm_trace(args);
    let (responses, summary) = run_batch(&svc, &requests, pool, 2 * pool.max(1));
    if let Some(path) = &trace {
        write_trace(path)?;
    }
    write_metrics(args)?;
    let mut out = String::new();
    for r in &responses {
        out.push_str(&r.to_json_line());
        out.push('\n');
    }
    match args.get("out") {
        Some(p) => {
            std::fs::write(p, &out)?;
            println!("wrote {p}");
        }
        None => print!("{out}"),
    }
    println!("batch: {}", summary.describe());
    if summary.errors > 0 {
        anyhow::bail!("{} of {} requests failed", summary.errors, summary.requests);
    }
    Ok(())
}

fn cmd_partition(args: &Args) -> anyhow::Result<()> {
    let model_kind = args.get_str("model", "transformer");
    let mut mesh = Mesh::parse(&args.get_str("mesh", "model=4"))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    // --pipeline stages=K[,microbatches=M][,axis=N]: appends a dedicated
    // (non-searchable) mesh axis when the spec doesn't already name one.
    let pipeline = match args.get("pipeline") {
        None => None,
        Some(s) => {
            let flag = automap::pipeline::parse_pipeline_flag(s)?;
            if !mesh.axes.iter().any(|a| a.name == flag.axis) {
                if mesh.axes.len() >= automap::partir::mesh::MAX_AXES {
                    anyhow::bail!(
                        "mesh is full ({} axes); cannot add pipeline axis '{}'",
                        mesh.axes.len(),
                        flag.axis
                    );
                }
                mesh.axes.push(automap::partir::mesh::Axis {
                    name: flag.axis.clone(),
                    size: flag.stages as i64,
                    searchable: false,
                });
            }
            Some(flag)
        }
    };
    let ranker = match args.get_str("filter", "heuristic").as_str() {
        "none" => RankerSpec::None,
        "heuristic" => RankerSpec::Heuristic,
        "learned" => RankerSpec::Learned {
            hlo_path: args.get_str("ranker", "artifacts/ranker.hlo.txt"),
        },
        other => anyhow::bail!("unknown filter '{other}'"),
    };
    let (label, func) = match args.get("program") {
        Some(path) => {
            // Same rule as the service wire schema: pick one source.
            if args.get("model").is_some() {
                anyhow::bail!("--model and --program are mutually exclusive");
            }
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            let f = parse_func(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            (format!("@{path}"), f)
        }
        None => {
            let f = build_model_func(&model_kind, args.get_usize("layers", 4)?)?;
            (model_kind.clone(), f)
        }
    };
    println!(
        "partitioning {label}: {} args, {} ops, mesh {}",
        func.num_args(),
        func.num_nodes(),
        mesh.describe()
    );

    // Paper Fig 5 constraints: --pin batch --shard tokens:0:batch
    let manual_axes: Vec<String> = args
        .get("pin")
        .map(|s| s.split(',').map(|a| a.trim().to_string()).filter(|a| !a.is_empty()).collect())
        .unwrap_or_default();
    let constraints: Vec<ShardingConstraint> = match args.get("shard") {
        None => Vec::new(),
        Some(s) => s
            .split(',')
            .filter(|p| !p.trim().is_empty())
            .map(ShardingConstraint::parse)
            .collect::<anyhow::Result<_>>()?,
    };

    let mut tactics = Vec::new();
    if !manual_axes.is_empty() || !constraints.is_empty() {
        tactics.push(Tactic::Manual { constraints, manual_axes });
    }
    if let Some(flag) = pipeline {
        tactics.push(Tactic::Pipeline {
            axis: flag.axis,
            stages: flag.stages,
            microbatches: flag.microbatches,
        });
    }
    tactics.push(Tactic::Filter { ranker, top_k: TOP_K });
    tactics.push(Tactic::Search {
        budget: args.get_usize("budget", 500)?,
        seed: args.get_u64("seed", 0)?,
        mcts: MctsConfig::default(),
    });
    tactics.push(Tactic::InferRest);
    tactics.push(Tactic::Lower);

    let trace = arm_trace(args);
    let mut session = Session::new(func, mesh);
    let plan = session.run(&tactics)?;
    if let Some(path) = &trace {
        write_trace(path)?;
    }
    println!("{}", plan.to_json().pretty());
    if let Some(out) = args.get("out") {
        std::fs::write(out, plan.to_json().pretty())?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `explain plan.json | responses.jsonl` — render the partitioning
/// narrative (mesh, cost, shardings, tactic timeline) for a plan
/// produced by `partition --out` or for each plan in a `batch --out`
/// responses file.
fn cmd_explain(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("explain needs a plan.json or responses.jsonl path"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    // A plan file is one (pretty-printed) JSON document; batch output is
    // JSONL with one response per line. Try whole-file first.
    if let Ok(doc) = automap::util::json::parse(&text) {
        print!("{}", explain_doc(&doc).map_err(|e| anyhow::anyhow!("{path}: {e:#}"))?);
        return Ok(());
    }
    let mut shown = 0usize;
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let doc = automap::util::json::parse(line)
            .map_err(|e| anyhow::anyhow!("{path}:{}: {e}", ln + 1))?;
        if doc.get("plan").is_none() {
            // Error responses carry no plan; note and move on.
            if let Some(id) = doc.get("id").and_then(|j| j.as_str()) {
                println!("== {id}: no plan (error response) ==\n");
            }
            continue;
        }
        if let Some(id) = doc.get("id").and_then(|j| j.as_str()) {
            println!("== {id} ==");
        }
        print!("{}", explain_doc(&doc).map_err(|e| anyhow::anyhow!("{path}:{}: {e:#}", ln + 1))?);
        println!();
        shown += 1;
    }
    if shown == 0 {
        anyhow::bail!("{path}: no plans found to explain");
    }
    Ok(())
}

/// Explain one JSON document: either a bare `PartitionPlan` or a plan
/// service response wrapping one under a `plan` key. Response wrappers
/// carrying degradation annotations (DESIGN.md §14) get them rendered
/// above the plan narrative so a degraded plan is never mistaken for a
/// full-quality one.
fn explain_doc(doc: &automap::util::json::Json) -> anyhow::Result<String> {
    let plan_json = doc.get("plan").unwrap_or(doc);
    let plan = automap::session::PartitionPlan::from_json(plan_json)?;
    let mut out = String::new();
    if let Some(notes) = automap::obs::explain_degradation(doc) {
        out.push_str(&notes);
    }
    out.push_str(&automap::obs::explain_plan(&plan));
    Ok(out)
}

/// `sync --cache-dir DIR --sync-dir DIR [--replica NAME]` — run ONE
/// anti-entropy round (DESIGN.md §15): canonicalize + publish the local
/// plan log as a snapshot in the shared sync dir, then pull every plan
/// a peer snapshot has that the local log lacks.
fn cmd_sync(args: &Args) -> anyhow::Result<()> {
    automap::util::failpoints::arm_from_env()?;
    let cache_dir = args
        .get("cache-dir")
        .ok_or_else(|| anyhow::anyhow!("sync needs --cache-dir (the plan log to replicate)"))?;
    let sync_dir = args
        .get("sync-dir")
        .ok_or_else(|| anyhow::anyhow!("sync needs --sync-dir (the shared mailbox dir)"))?;
    let replica = match args.get("replica") {
        Some(r) => r.to_string(),
        None => format!("replica-{}", std::process::id()),
    };
    let tier = automap::service::DiskTier::open(std::path::Path::new(cache_dir))?;
    let transport = automap::service::MailboxTransport::new(std::path::Path::new(sync_dir))?;
    let report = automap::service::sync_once(&replica, &tier, &transport)?;
    let stats = tier.stats();
    println!(
        "sync: replica {replica} saw {} peer(s): {} records pulled, {} conflicts, \
         {} frames quarantined, {} retries, {} skipped ({} version-skewed); \
         log now {} plans in {} bytes",
        report.peers,
        report.records_pulled,
        report.conflicts,
        report.frames_quarantined,
        report.retries,
        report.peers_skipped,
        report.peer_skew,
        stats.entries,
        stats.file_bytes,
    );
    write_metrics(args)?;
    Ok(())
}

fn figure_cmd(
    args: &Args,
    run: impl Fn(&FigureSetup, &str) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    let mut setup = FigureSetup {
        layers: args.get_usize("layers", 4)?,
        budgets: args.get_usize_list("budgets", &[50, 100, 250, 500, 1000, 2000])?,
        attempts: args.get_usize("attempts", 20)?,
        seed: args.get_u64("seed", 42)?,
        ranker_path: args.get_str("ranker", "artifacts/ranker.hlo.txt"),
    };
    if let Some(path) = args.get("config") {
        let cfg = cfgfile::load(path)?;
        cfgfile::apply_figure(&mut setup, &cfg);
    }
    let out_dir = args.get_str("out-dir", "results");
    let t0 = std::time::Instant::now();
    run(&setup, &out_dir)?;
    println!("done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
