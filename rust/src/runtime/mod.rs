//! PJRT runtime wrapper around the `xla` crate: load AOT artifacts
//! (HLO text) and execute them from the rust hot path. Compiles as an
//! erroring stub unless the `pjrt` cargo feature is enabled.

pub mod pjrt;

pub use pjrt::{pjrt_available, Executable, Input, Runtime};
