//! PJRT runtime wrapper around the `xla` crate: load AOT artifacts
//! (HLO text) and execute them from the rust hot path.

pub mod pjrt;

pub use pjrt::{Executable, Input, Runtime};
