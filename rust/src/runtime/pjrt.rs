//! PJRT runtime: loads AOT-compiled HLO text (produced by
//! `python/compile/aot.py`) and executes it on the CPU PJRT client via
//! the `xla` crate. This is the ONLY place python-authored computation
//! enters the rust system — python itself never runs at search time.
//!
//! Interchange is HLO *text*, not serialized HloModuleProto: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::{Context, Result};

/// A PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

/// An input tensor for execution.
pub enum Input {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text module from `path`.
    pub fn load_hlo_text(&self, path: &str) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("compiling HLO")?;
        Ok(Executable { exe })
    }
}

fn to_literal(i: &Input) -> Result<xla::Literal> {
    Ok(match i {
        Input::F32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
        Input::I32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
    })
}

impl Executable {
    /// Execute with the given inputs; the module must return a tuple
    /// (aot.py lowers with `return_tuple=True`). Returns each tuple
    /// element flattened to f32.
    pub fn run_f32(&self, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let elems = result.decompose_tuple().context("decomposing result tuple")?;
        elems
            .into_iter()
            .map(|l| l.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need artifacts live in rust/tests/; here we only
    // check client construction (always available on CPU).
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::new().unwrap();
        assert!(!rt.platform().is_empty());
    }
}
