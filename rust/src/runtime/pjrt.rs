//! PJRT runtime: loads AOT-compiled HLO text (produced by
//! `python/compile/aot.py`) and executes it on the CPU PJRT client via
//! the `xla` crate. This is the ONLY place python-authored computation
//! enters the rust system — python itself never runs at search time.
//!
//! Interchange is HLO *text*, not serialized HloModuleProto: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` crate is only reachable in environments with the PJRT
//! toolchain installed, so the real implementation is gated behind the
//! `pjrt` cargo feature. Without it this module compiles as a stub whose
//! constructors error, and every caller (the `Learned` ranker, the
//! figure harnesses) falls back to the heuristic ranker.

/// An input tensor for execution.
pub enum Input {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::Input;
    use anyhow::{Context, Result};

    /// A PJRT client (CPU).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// A compiled executable.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
    }

    impl Runtime {
        pub fn new() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile an HLO-text module from `path`.
        pub fn load_hlo_text(&self, path: &str) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).context("compiling HLO")?;
            Ok(Executable { exe })
        }
    }

    fn to_literal(i: &Input) -> Result<xla::Literal> {
        Ok(match i {
            Input::F32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
            Input::I32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
        })
    }

    impl Executable {
        /// Execute with the given inputs; the module must return a tuple
        /// (aot.py lowers with `return_tuple=True`). Returns each tuple
        /// element flattened to f32.
        pub fn run_f32(&self, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
            let lits: Vec<xla::Literal> =
                inputs.iter().map(to_literal).collect::<Result<_>>()?;
            let mut result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
                .to_literal_sync()
                .context("fetching result")?;
            let elems = result.decompose_tuple().context("decomposing result tuple")?;
            elems
                .into_iter()
                .map(|l| l.to_vec::<f32>().context("reading f32 output"))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::Input;
    use anyhow::{bail, Result};

    const STUB_MSG: &str = "automap was built without the `pjrt` cargo feature; \
         the learned ranker needs the xla/PJRT toolchain — use the heuristic \
         ranker, or rebuild with `--features pjrt` where `xla` is available";

    /// Stub PJRT client: construction always errors (see module docs).
    pub struct Runtime {
        _priv: (),
    }

    /// Stub executable (never constructed).
    pub struct Executable {
        _priv: (),
    }

    impl Runtime {
        pub fn new() -> Result<Runtime> {
            bail!("{}", STUB_MSG)
        }

        pub fn platform(&self) -> String {
            "pjrt-stub".to_string()
        }

        pub fn load_hlo_text(&self, _path: &str) -> Result<Executable> {
            bail!("{}", STUB_MSG)
        }
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
            bail!("{}", STUB_MSG)
        }
    }
}

pub use imp::{Executable, Runtime};

/// True when this build can actually execute HLO (the `pjrt` feature).
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

#[allow(dead_code)]
fn _input_fields_are_read_by_both_impls(i: &Input) -> usize {
    // The stub build never reads Input payloads; this keeps the fields
    // warning-free without cfg-ing the type itself.
    match i {
        Input::F32(d, dims) => d.len() + dims.len(),
        Input::I32(d, dims) => d.len() + dims.len(),
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    // Runtime tests that need artifacts live in rust/tests/; here we only
    // check client construction (always available on CPU when the pjrt
    // feature is on).
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::new().unwrap();
        assert!(!rt.platform().is_empty());
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_loudly() {
        let err = Runtime::new().err().expect("stub must not construct");
        assert!(format!("{err}").contains("pjrt"));
        assert!(!pjrt_available());
    }
}
