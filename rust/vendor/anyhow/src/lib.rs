//! Minimal offline stand-in for the `anyhow` crate: the API subset this
//! workspace uses (`Result`, `Error`, `anyhow!`, `bail!`, `ensure!`, the
//! `Context` extension trait) with context chains rendered by `{:#}`.
//!
//! The real crate is unavailable because the registry is unreachable in
//! the build environment; this vendored version keeps call sites
//! source-compatible so swapping the real dependency back in is a
//! one-line Cargo.toml change.

use std::fmt;

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error with an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the error chain, outermost context first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The root (innermost) cause's message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().map(|e| e.msg.as_str()).unwrap_or("")
    }
}

/// Iterator over an error's cause chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;
    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain: "outer: inner: root".
            let mut first = true;
            for e in self.chain() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(&e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut causes = self.chain().skip(1).peekable();
        if causes.peek().is_some() {
            f.write_str("\n\nCaused by:")?;
            for e in causes {
                write!(f, "\n    {}", e.msg)?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our string chain.
        let mut msgs = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error { msg, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chain_renders_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.root_cause(), "missing");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("empty").unwrap_err()), "empty");
    }

    #[test]
    fn macros_build_errors() {
        fn fails(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            bail!("unreachable {}", 1);
        }
        assert_eq!(format!("{}", fails(false).unwrap_err()), "flag was false");
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
    }
}
