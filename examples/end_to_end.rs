//! END-TO-END DRIVER — exercises every layer of the stack on a real
//! workload, proving they compose (recorded in EXPERIMENTS.md):
//!
//!   1. build a transformer *training step* graph (fwd+bwd+Adam) in the
//!      base dialect and numerically train it with the reference
//!      interpreter for a few steps (loss curve);
//!   2-4. run the Session tactic pipeline — Filter (AOT-compiled
//!      Interaction-Network ranker through PJRT when artifacts + the
//!      `pjrt` feature are present, heuristic fallback otherwise) →
//!      Search on a memory-pressured TPU-v3 → InferRest → Lower — and
//!      verify Megatron via collective statistics.
//!
//!     make artifacts && cargo run --release --offline --example end_to_end

use automap::cost::composite::CostWeights;
use automap::ir::interp::{eval_all, Tensor};
use automap::models::megatron;
use automap::models::transformer::{build_transformer, TransformerConfig};
use automap::partir::mesh::{AxisId, Mesh};
use automap::partir::program::PartirProgram;
use automap::search::env::SearchOptions;
use automap::search::experiment::pressured_device;
use automap::session::{RankerSpec, Session, Tactic};
use automap::sim::device::Device;
use automap::util::rng::Rng;
use automap::util::stats::{fmt_bytes, fmt_secs};

fn main() {
    // ---- 1. model + a short REAL training run (numeric, interpreter) ----
    let train_cfg = TransformerConfig {
        layers: 2,
        d_model: 32,
        n_heads: 2,
        d_ff: 128,
        vocab: 64,
        seq: 16,
        batch: 2,
        training: true,
    };
    let tm = build_transformer(&train_cfg);
    println!(
        "[1/4] training-step graph: {} args, {} ops — running 5 Adam steps numerically",
        tm.func.num_args(),
        tm.func.num_nodes()
    );
    let mut rng = Rng::new(123);
    let mut args: Vec<Tensor> = tm
        .func
        .args
        .iter()
        .map(|a| {
            let n = a.ty.num_elements() as usize;
            match a.name.as_str() {
                "causal_mask" => {
                    let s = train_cfg.seq as usize;
                    let mut d = vec![0.0; s * s];
                    for i in 0..s {
                        for j in (i + 1)..s {
                            d[i * s + j] = -1e9;
                        }
                    }
                    Tensor::new(&a.ty.dims, d)
                }
                "tokens" | "targets" => Tensor::new(
                    &a.ty.dims,
                    (0..n).map(|_| rng.gen_range(train_cfg.vocab as usize) as f64).collect(),
                ),
                _ if a.name.ends_with(".adam_m") || a.name.ends_with(".adam_v") => {
                    Tensor::new(&a.ty.dims, vec![0.0; n])
                }
                _ => Tensor::new(
                    &a.ty.dims,
                    (0..n).map(|_| (rng.gen_f64() * 2.0 - 1.0) * 0.05).collect(),
                ),
            }
        })
        .collect();
    let mut losses = Vec::new();
    for step in 0..5 {
        let vals = eval_all(&tm.func, &args);
        let loss = vals[tm.loss.index()].data[0];
        losses.push(loss);
        println!("      step {step}: loss = {loss:.4}");
        for (i, &p) in tm.params.iter().enumerate() {
            args[p.index()] = vals[tm.func.outputs[3 * i].index()].clone();
            let m_id = tm.func.args.iter().position(|a| {
                a.name == format!("{}.adam_m", tm.func.args[p.index()].name)
            });
            if let Some(mi) = m_id {
                args[mi] = vals[tm.func.outputs[3 * i + 1].index()].clone();
                args[mi + 1] = vals[tm.func.outputs[3 * i + 2].index()].clone();
            }
        }
    }
    assert!(losses.last().unwrap() < losses.first().unwrap(), "loss must decrease");
    println!("      loss curve OK ({:.4} -> {:.4})", losses[0], losses[4]);

    // ---- 2. Session pipeline: filter through the AOT artifacts -----------
    let model = build_transformer(&TransformerConfig::tiny(4));
    let mesh = Mesh::new(&[("model", 4)]);
    let program = PartirProgram::new(model.func.clone(), mesh.clone());
    let ranker_path = "artifacts/ranker.hlo.txt";

    let w = CostWeights::default();
    let probe = megatron::reference_evaluation(&program, &model, AxisId(0), &Device::tpu_v3(), &w);
    let device = pressured_device(&probe);
    let reference = megatron::reference_evaluation(&program, &model, AxisId(0), &device, &w);

    let mut session = Session::with_options(
        model.func.clone(),
        mesh,
        device,
        w,
        SearchOptions::default(),
    );

    // ---- 3. MCTS over the filtered worklist ------------------------------
    let t0 = std::time::Instant::now();
    let budget = 1500;
    let plan = session
        .run(&[
            Tactic::filter(RankerSpec::Auto { hlo_path: ranker_path.to_string() }),
            Tactic::search(budget, 2024),
            Tactic::InferRest,
            Tactic::Lower,
        ])
        .expect("pipeline");
    println!(
        "[2/4] ranker: {} args -> top-{} (see trace; run `make artifacts` + \
         `--features pjrt` for the learned GNN)",
        session.program.func.num_args(),
        plan.worklist_size
    );
    println!(
        "[3/4] MCTS: {budget} episodes in {:.2}s (best at {})",
        t0.elapsed().as_secs_f64(),
        plan.episodes_to_best
    );

    // ---- 4. SPMD + verdict + simulated step time --------------------------
    let verdict = megatron::check(&plan.eval, &reference);
    println!(
        "[4/4] result: peak {} (fits={}), {} AR + {} AG, sim step {} \
         (megatron ref {}) | megatron={} near={}",
        fmt_bytes(plan.eval.memory.peak_bytes as f64),
        plan.eval.fits_memory,
        plan.eval.collectives.all_reduce_count,
        plan.eval.collectives.all_gather_count,
        fmt_secs(plan.eval.runtime.total_seconds()),
        fmt_secs(reference.runtime.total_seconds()),
        verdict.is_megatron,
        verdict.near_megatron
    );
    for line in &plan.trace {
        println!("      {line}");
    }
    assert!(plan.eval.fits_memory, "end-to-end must fit device memory");
    assert!(
        verdict.is_megatron || verdict.near_megatron,
        "end-to-end should land (near-)Megatron"
    );
    println!("END-TO-END OK");
}
