//! Discover Megatron sharding on a transformer training step with the
//! Session pipeline, and verify it with the collective-statistics
//! detector (paper §3).
//!
//!     cargo run --release --offline --example transformer_megatron -- [layers] [budget]

use automap::cost::composite::CostWeights;
use automap::models::megatron;
use automap::models::transformer::{build_transformer, TransformerConfig};
use automap::partir::mesh::{AxisId, Mesh};
use automap::partir::program::PartirProgram;
use automap::search::env::SearchOptions;
use automap::search::experiment::pressured_device;
use automap::session::{Session, Tactic};
use automap::sim::device::Device;
use automap::util::stats::{fmt_bytes, fmt_secs};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let layers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let budget: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2000);

    let model = build_transformer(&TransformerConfig::tiny(layers));
    println!(
        "transformer update fn: {} layers, {} args, {} ops",
        layers,
        model.func.num_args(),
        model.func.num_nodes()
    );
    let mesh = Mesh::new(&[("model", 4)]);
    let program = PartirProgram::new(model.func.clone(), mesh.clone());
    let w = CostWeights::default();

    // Expert reference (Megatron) and a memory-pressured TPU-v3.
    let probe = megatron::reference_evaluation(&program, &model, AxisId(0), &Device::tpu_v3(), &w);
    let device = pressured_device(&probe);
    let reference = megatron::reference_evaluation(&program, &model, AxisId(0), &device, &w);
    println!(
        "device HBM: {} | megatron peak {} / {} all-reduces / sim {}",
        fmt_bytes(device.hbm_bytes as f64),
        fmt_bytes(reference.memory.peak_bytes as f64),
        reference.collectives.all_reduce_count,
        fmt_secs(reference.runtime.total_seconds())
    );

    // Session pipeline: unfiltered search + infer-rest + lower.
    let mut session = Session::with_options(
        model.func.clone(),
        mesh,
        device,
        w,
        SearchOptions::default(),
    );
    let t0 = std::time::Instant::now();
    let plan = session
        .run(&[Tactic::search(budget, 42), Tactic::InferRest, Tactic::Lower])
        .expect("pipeline");
    let verdict = megatron::check(&plan.eval, &reference);

    println!(
        "search: {budget} episodes in {:.2}s, best found at episode {}",
        t0.elapsed().as_secs_f64(),
        plan.episodes_to_best
    );
    println!(
        "found: peak {} | {} all-reduces + {} all-gathers ({}) | sim {}",
        fmt_bytes(plan.eval.memory.peak_bytes as f64),
        plan.eval.collectives.all_reduce_count,
        plan.eval.collectives.all_gather_count,
        fmt_bytes(plan.eval.collectives.total_bytes() as f64),
        fmt_secs(plan.eval.runtime.total_seconds())
    );
    println!(
        "verdict: megatron={} near={} redundant_collectives={}",
        verdict.is_megatron, verdict.near_megatron, verdict.redundant_collectives
    );
    for line in &plan.trace {
        println!("  {line}");
    }
}
