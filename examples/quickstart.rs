//! Quickstart: the paper's Figure 2/3 walkthrough on a single dense
//! layer — base IR, a tiling decision, propagation, and SPMD lowering.
//!
//!     cargo run --release --offline --example quickstart

use automap::ir::{ArgKind, GraphBuilder, TensorType, ValueId};
use automap::partir::actions::{Action, DecisionState};
use automap::partir::mesh::{AxisId, Mesh};
use automap::partir::printer::print_partir;
use automap::partir::program::PartirProgram;
use automap::spmd::lower::lower;
use automap::spmd::printer::print_spmd;

fn main() {
    // Figure 2 (top): a linear layer  y = x @ w + b.
    let mut b = GraphBuilder::new("main");
    let _x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
    let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
    let bias = b.arg("b", TensorType::f32(&[64]), ArgKind::Parameter);
    let dot = b.matmul(ValueId(0), w);
    let ty = b.ty(dot).clone();
    let bb = b.broadcast_to(bias, ty);
    let out = b.add(dot, bb);
    b.output(out);
    let func = b.finish();

    println!("=== base dialect (Fig 2 top) ===");
    println!("{}", automap::ir::printer::print_func(&func));

    // Declare a 1-D mesh {"shard": 2} and tile w on dim 1.
    let mesh = Mesh::new(&[("shard", 2)]);
    let program = PartirProgram::new(func, mesh);
    let state = DecisionState {
        actions: vec![
            Action::Tile { v: ValueId(1), dim: 1, axis: AxisId(0) },
            Action::InferRest,
        ],
        atomic: vec![ValueId(0)], // x stays replicated (Fig 2 bottom: atomic)
    };
    let (dm, stats) = program.apply(&state);

    println!("=== PartIR view after tiling + propagation (Fig 2 bottom) ===");
    println!("{}", print_partir(&program.func, &program.mesh, &dm, &state.atomic));
    println!("(propagation assigned {} value-axis tilings)", stats.assigned);

    // Lower to SPMD (Fig 3).
    let spmd = lower(&program.func, &program.mesh, &program.prop, &dm);
    println!("=== SPMD dialect (Fig 3) ===");
    println!("{}", print_spmd(&spmd));
    println!(
        "collectives: {} (column sharding of a dense layer needs none)",
        spmd.collectives.len()
    );
    assert!(spmd.collectives.is_empty());
}
