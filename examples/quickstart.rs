//! Quickstart: the paper's Figure 5 workflow on a small MLP training
//! step — a `Session` running a composable tactic pipeline with a
//! `Manual` tactic pinning the data-parallel axis, then search over the
//! remaining "model" axis, plus the Figure 2/3 PartIR/SPMD views.
//!
//!     cargo run --release --offline --example quickstart

use automap::models::mlp::{build_mlp, MlpConfig};
use automap::partir::mesh::Mesh;
use automap::partir::printer::print_partir;
use automap::session::{Session, ShardingConstraint, Tactic};
use automap::spmd::lower::lower;
use automap::spmd::printer::print_spmd;

fn main() {
    // Figure 5:  automap(update_fn, mesh={"batch":2,"model":4},
    //                    manual_axes=["batch"])
    let model = build_mlp(&MlpConfig::small());
    let mesh = Mesh::new(&[("batch", 2), ("model", 4)]);
    let mut session = Session::new(model.func, mesh);

    let plan = session
        .run(&[
            // User constraints: "batch" stays manually managed (the user
            // runs data parallelism), and the inputs are pre-sharded on
            // it — exactly the pmap-style starting point of Fig 5.
            Tactic::Manual {
                constraints: vec![
                    ShardingConstraint::new("x", 0, "batch"),
                    ShardingConstraint::new("target", 0, "batch"),
                ],
                manual_axes: vec!["batch".to_string()],
            },
            // Automated half: search the "model" axis, close over the
            // rest, lower to SPMD with a cost evaluation.
            Tactic::search(400, 0),
            Tactic::InferRest,
            Tactic::Lower,
        ])
        .expect("pipeline");

    println!("=== PartIR view after the pipeline (Fig 2) ===");
    println!(
        "{}",
        print_partir(
            &session.program.func,
            &session.program.mesh,
            session.dist_map(),
            &session.state().atomic,
        )
    );

    let spmd = lower(
        &session.program.func,
        &session.program.mesh,
        &session.program.prop,
        session.dist_map(),
    );
    println!("=== SPMD dialect (Fig 3) ===");
    println!("{}", print_spmd(&spmd));

    println!("=== decision trace ===");
    for line in plan.trace.iter() {
        println!("  {line}");
    }
    println!("=== partition plan ===");
    println!("{}", plan.to_json().pretty());

    // The manual axis is the user's: search must never assign it to a
    // parameter, while the pinned input sharding survives the pipeline.
    let x = plan.input_specs.iter().find(|s| s.name == "x").expect("x spec");
    assert!(x.tiled_on("batch"));
    for s in &plan.input_specs {
        if s.name.ends_with("/w") || s.name.ends_with("/b") {
            assert!(!s.tiled_on("batch"), "search assigned the manual axis to {}", s.name);
        }
    }
    println!("quickstart OK: batch stayed manual, pinned shardings survived");
}
