//! Walkthrough of the partition-plan service (DESIGN.md §9): fingerprint
//! cache, in-flight dedup, and the root-parallel executor.
//!
//!     cargo run --release --example plan_service

use automap::service::{run_batch, PartitionRequest, PlanService, ServiceConfig};

fn request(id: &str, seed: u64) -> PartitionRequest {
    PartitionRequest {
        id: id.to_string(),
        model: "mlp".to_string(),
        mesh: "batch=2,model=4".to_string(),
        pin: vec!["batch".to_string()],
        shard: vec!["x:0:batch".to_string()],
        budget: 200,
        seed,
        workers: 4,
        ..Default::default()
    }
}

fn main() {
    let svc = PlanService::new(ServiceConfig::default());

    // A burst of eight requests over two unique configurations: the
    // service runs exactly two searches and serves the rest from the
    // plan cache (or by joining an identical in-flight search).
    let requests: Vec<PartitionRequest> =
        (0..8).map(|i| request(&format!("r{i}"), (i % 2) as u64)).collect();
    let (responses, summary) = run_batch(&svc, &requests, 2, 4);

    println!("== responses ==");
    for r in &responses {
        println!(
            "{:>3}  fingerprint={}  cached={}  dedup={}",
            r.id,
            r.fingerprint,
            r.cached,
            r.dedup
        );
    }
    println!("\n== summary ==\n{}", summary.describe());
    assert_eq!(summary.searches, 2, "two unique fingerprints, two searches");

    // Determinism: a repeat of r0's configuration in a fresh service
    // reproduces the same plan document byte for byte.
    let fresh = PlanService::new(ServiceConfig::default());
    let again = fresh.handle(&request("again", 0));
    assert_eq!(
        again.plan_json, responses[0].plan_json,
        "fixed (seed, K) reproduces the identical plan"
    );
    println!("\nrepeat run in a fresh service reproduced r0's plan byte-identically");

    let stats = svc.cache_stats();
    println!(
        "cache: {} entries, {} bytes, {} hits, {} evictions",
        stats.entries, stats.bytes, stats.hits, stats.evictions
    );
}
