//! Compiler hints via named-scope grouping (paper Figs 8–9): one set of
//! decisions per repeated block collapses the search space, making deep
//! transformers solvable without brittle cross-layer propagation.
//!
//!     cargo run --release --offline --example grouping_hints -- [layers]

use automap::cost::composite::CostWeights;
use automap::models::megatron;
use automap::models::transformer::{build_transformer, TransformerConfig};
use automap::partir::mesh::{AxisId, Mesh};
use automap::partir::program::PartirProgram;
use automap::search::env::{RewriteEnv, SearchOptions};
use automap::search::experiment::pressured_device;
use automap::search::mcts::{search, MctsConfig};
use automap::sim::device::Device;

fn run(program: &PartirProgram, reference: &automap::cost::composite::Evaluation,
       device: &Device, grouping: bool, budget: usize) -> (bool, usize, usize) {
    let opts = SearchOptions {
        grouping,
        cross_layer_tying: false, // no shared-dependency propagation (Fig 9)
        ..Default::default()
    };
    let worklist = RewriteEnv::default_worklist(program);
    let env = RewriteEnv::new(program, device.clone(), CostWeights::default(), opts, &worklist);
    let res = search(&env, budget, 11, MctsConfig::default());
    let verdict = megatron::check(&res.best_eval, reference);
    (verdict.is_megatron, env.targets.len(), res.episodes_to_best)
}

fn main() {
    let layers: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let model = build_transformer(&TransformerConfig::tiny(layers));
    let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
    let w = CostWeights::default();
    let probe = megatron::reference_evaluation(&program, &model, AxisId(0), &Device::tpu_v3(), &w);
    let device = pressured_device(&probe);
    let reference = megatron::reference_evaluation(&program, &model, AxisId(0), &device, &w);

    println!("{layers}-layer transformer, no cross-layer propagation:");
    for budget in [250usize, 1000] {
        let (hit_g, targets_g, ep_g) = run(&program, &reference, &device, true, budget);
        let (hit_u, targets_u, _) = run(&program, &reference, &device, false, budget);
        println!(
            "  budget {budget:>5}: grouped({targets_g} targets) megatron={hit_g} (ep {ep_g}) | \
             ungrouped({targets_u} targets) megatron={hit_u}"
        );
    }
    println!("-> grouping makes the deep model solvable; ungrouped search is lost.");
}
