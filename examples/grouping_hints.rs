//! Compiler hints via named-scope grouping (paper Figs 8–9): one set of
//! decisions per repeated block collapses the search space, making deep
//! transformers solvable without brittle cross-layer propagation. Runs
//! through the Session pipeline with grouping toggled in the options.
//!
//!     cargo run --release --offline --example grouping_hints -- [layers]

use automap::cost::composite::{CostWeights, Evaluation};
use automap::models::megatron;
use automap::models::transformer::{build_transformer, TransformerConfig};
use automap::partir::mesh::{AxisId, Mesh};
use automap::partir::program::PartirProgram;
use automap::search::env::SearchOptions;
use automap::search::experiment::pressured_device;
use automap::session::{Session, Tactic};
use automap::sim::device::Device;

fn run(
    func: &automap::ir::Func,
    reference: &Evaluation,
    device: &Device,
    grouping: bool,
    budget: usize,
) -> (bool, usize, usize) {
    let opts = SearchOptions {
        grouping,
        cross_layer_tying: false, // no shared-dependency propagation (Fig 9)
        ..Default::default()
    };
    let mut session = Session::with_options(
        func.clone(),
        Mesh::new(&[("model", 4)]),
        device.clone(),
        CostWeights::default(),
        opts,
    );
    let plan = session
        .run(&[Tactic::search(budget, 11), Tactic::InferRest, Tactic::Lower])
        .expect("pipeline");
    let verdict = megatron::check(&plan.eval, reference);
    (verdict.is_megatron, plan.targets, plan.episodes_to_best)
}

fn main() {
    let layers: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let model = build_transformer(&TransformerConfig::tiny(layers));
    let program = PartirProgram::new(model.func.clone(), Mesh::new(&[("model", 4)]));
    let w = CostWeights::default();
    let probe = megatron::reference_evaluation(&program, &model, AxisId(0), &Device::tpu_v3(), &w);
    let device = pressured_device(&probe);
    let reference = megatron::reference_evaluation(&program, &model, AxisId(0), &device, &w);

    println!("{layers}-layer transformer, no cross-layer propagation:");
    for budget in [250usize, 1000] {
        let (hit_g, targets_g, ep_g) = run(&model.func, &reference, &device, true, budget);
        let (hit_u, targets_u, _) = run(&model.func, &reference, &device, false, budget);
        println!(
            "  budget {budget:>5}: grouped({targets_g} targets) megatron={hit_g} (ep {ep_g}) | \
             ungrouped({targets_u} targets) megatron={hit_u}"
        );
    }
    println!("-> grouping makes the deep model solvable; ungrouped search is lost.");
}
