//! Walkthrough of the textual IR interchange format (DESIGN.md §10):
//! print a program, parse it back exactly, submit it to the plan
//! service as an arbitrary-program request, and watch it share a cache
//! line with the equivalent built-in-model request.
//!
//!     cargo run --release --example textual_ir

use automap::ir::{parse_func, print_func, ArgKind, GraphBuilder, TensorType};
use automap::models::mlp::{build_mlp, MlpConfig};
use automap::service::{PartitionRequest, PlanService, ServiceConfig};

fn main() {
    // 1. Any program prints to the MLIR-flavoured textual form — and
    //    parses back to the exact same function (names, scopes, attrs).
    let mut b = GraphBuilder::new("linear");
    let x = b.arg("x", TensorType::f32(&[8, 16]), ArgKind::Input);
    let w = b.arg("w", TensorType::f32(&[16, 64]), ArgKind::Parameter);
    let bias = b.arg("b", TensorType::f32(&[64]), ArgKind::Parameter);
    let y = b.matmul(x, w);
    let yty = b.ty(y).clone();
    let bb = b.broadcast_to(bias, yty);
    let out = b.add(y, bb);
    b.output(out);
    let f = b.finish();

    let text = print_func(&f);
    println!("== printed ==\n{text}");
    let parsed = parse_func(&text).expect("printed programs always parse");
    assert_eq!(parsed, f, "parse(print(f)) == f");

    // 2. Parse errors carry line/column positions — this is what an
    //    external frontend sees when it sends a malformed program.
    let bad = "func @broken(%arg0: tensor<4xf32> {input})\n    -> () {\n  \
               %0 = frobnicate %arg0 : tensor<4xf32>\n  return\n}\n";
    let err = parse_func(bad).unwrap_err();
    println!("== diagnostics ==\n{err}\n");

    // 3. The service accepts programs as text: the fingerprint is
    //    computed over the *parsed* structure, so this request hits the
    //    same cache line as the equivalent built-in-model request.
    let svc = PlanService::new(ServiceConfig::default());
    let model_req = PartitionRequest {
        id: "builtin".to_string(),
        model: "mlp".to_string(),
        mesh: "batch=2,model=4".to_string(),
        budget: 120,
        seed: 3,
        workers: 2,
        ..Default::default()
    };
    let first = svc.handle(&model_req);
    assert!(first.error.is_none(), "{:?}", first.error);

    let program_req = PartitionRequest {
        id: "external".to_string(),
        model: String::new(),
        program: Some(print_func(&build_mlp(&MlpConfig::small()).func)),
        ..model_req.clone()
    };
    let second = svc.handle(&program_req);
    assert!(second.error.is_none(), "{:?}", second.error);
    assert_eq!(first.fingerprint, second.fingerprint, "same structure, same fingerprint");
    assert!(second.cached, "program request served from the model request's cache line");
    assert_eq!(first.plan_json, second.plan_json, "byte-identical plan");
    println!(
        "== service ==\nbuiltin:  fingerprint={} cached={}\nexternal: fingerprint={} cached={}",
        first.fingerprint, first.cached, second.fingerprint, second.cached
    );
    println!("\nsearches run: {} (one search served both)", svc.searches_run());
}
