//! GraphNet partitioning (paper §3 "Other models"): the Session pipeline
//! discovers input-edge sharding for an Interaction-Network training step.
//!
//!     cargo run --release --offline --example graphnet_sharding

use automap::cost::composite::{evaluate, CostWeights};
use automap::models::graphnet::{build_graphnet, GraphNetConfig};
use automap::partir::dist::DistMap;
use automap::partir::mesh::Mesh;
use automap::partir::program::PartirProgram;
use automap::search::env::SearchOptions;
use automap::session::{RankerSpec, Session, Tactic};
use automap::sim::device::Device;
use automap::util::stats::fmt_bytes;

fn main() {
    let cfg = GraphNetConfig {
        num_nodes: 256,
        num_edges: 4096,
        node_dim: 64,
        hidden: 128,
        rounds: 3,
        training: true,
    };
    let m = build_graphnet(&cfg);
    println!(
        "graphnet update fn: {} nodes x {} edges, {} args, {} ops",
        cfg.num_nodes,
        cfg.num_edges,
        m.func.num_args(),
        m.func.num_nodes()
    );

    let mesh = Mesh::new(&[("shard", 4)]);
    // Memory pressure relative to this model.
    let probe_prog = PartirProgram::new(m.func.clone(), mesh.clone());
    let dm0 = DistMap::new(&probe_prog.func, &probe_prog.mesh);
    let probe = evaluate(&probe_prog, &dm0, &Device::tpu_v3(), &CostWeights::default());
    let device = Device {
        hbm_bytes: (probe.memory.peak_bytes as f64 * 0.5) as i64,
        ..Device::tpu_v3()
    };
    println!(
        "replicated peak {} vs device HBM {}",
        fmt_bytes(probe.memory.peak_bytes as f64),
        fmt_bytes(device.hbm_bytes as f64)
    );

    let mut session = Session::with_options(
        m.func,
        mesh,
        device,
        CostWeights::default(),
        SearchOptions::default(),
    );
    let plan = session
        .run(&[
            Tactic::filter(RankerSpec::None), // MCTS-only: full worklist
            Tactic::search(1500, 7),
            Tactic::InferRest,
            Tactic::Lower,
        ])
        .expect("pipeline");

    println!("sharded inputs:");
    for s in plan.sharded_inputs() {
        println!("  {} -> {:?}", s.name, s.tilings);
    }
    println!(
        "peak {} (fits={}), {} all-reduces, sim runtime {:.3}ms",
        fmt_bytes(plan.eval.memory.peak_bytes as f64),
        plan.eval.fits_memory,
        plan.eval.collectives.all_reduce_count,
        plan.eval.runtime.total_seconds() * 1e3
    );

    // The practitioner strategy the paper mentions: edge tensors sharded.
    let edge_sharded = plan
        .input_specs
        .iter()
        .any(|s| {
            (s.name == "edges" || s.name == "senders" || s.name == "receivers")
                && !s.replicated()
        });
    println!("discovered input-edge sharding: {edge_sharded}");
}
