//! GraphNet partitioning (paper §3 "Other models"): automap discovers
//! input-edge sharding for an Interaction-Network training step.
//!
//!     cargo run --release --offline --example graphnet_sharding

use automap::coordinator::automap::{Automap, AutomapOptions, Filter};
use automap::cost::composite::{evaluate, CostWeights};
use automap::models::graphnet::{build_graphnet, GraphNetConfig};
use automap::partir::dist::DistMap;
use automap::partir::mesh::Mesh;
use automap::partir::program::PartirProgram;
use automap::sim::device::Device;
use automap::util::stats::fmt_bytes;

fn main() {
    let cfg = GraphNetConfig {
        num_nodes: 256,
        num_edges: 4096,
        node_dim: 64,
        hidden: 128,
        rounds: 3,
        training: true,
    };
    let m = build_graphnet(&cfg);
    println!(
        "graphnet update fn: {} nodes x {} edges, {} args, {} ops",
        cfg.num_nodes,
        cfg.num_edges,
        m.func.num_args(),
        m.func.num_nodes()
    );

    let mesh = Mesh::new(&[("shard", 4)]);
    // Memory pressure relative to this model.
    let probe_prog = PartirProgram::new(m.func.clone(), mesh.clone());
    let dm0 = DistMap::new(&probe_prog.func, &probe_prog.mesh);
    let probe = evaluate(&probe_prog, &dm0, &Device::tpu_v3(), &CostWeights::default());
    let device = Device {
        hbm_bytes: (probe.memory.peak_bytes as f64 * 0.5) as i64,
        ..Device::tpu_v3()
    };
    println!(
        "replicated peak {} vs device HBM {}",
        fmt_bytes(probe.memory.peak_bytes as f64),
        fmt_bytes(device.hbm_bytes as f64)
    );

    let opts = AutomapOptions {
        device,
        budget: 1500,
        seed: 7,
        filter: Filter::None,
        ..Default::default()
    };
    let am = Automap::new(m.func, mesh, opts);
    let report = am.partition().expect("partition");

    println!("sharded inputs:");
    for s in report.input_specs.iter().filter(|s| !s.tilings.is_empty()) {
        println!("  {} -> {:?}", s.name, s.tilings);
    }
    println!(
        "peak {} (fits={}), {} all-reduces, sim runtime {:.3}ms",
        fmt_bytes(report.eval.memory.peak_bytes as f64),
        report.eval.fits_memory,
        report.eval.collectives.all_reduce_count,
        report.eval.runtime.total_seconds() * 1e3
    );

    // The practitioner strategy the paper mentions: edge tensors sharded.
    let edge_sharded = report
        .input_specs
        .iter()
        .any(|s| (s.name == "edges" || s.name == "senders" || s.name == "receivers")
            && !s.tilings.is_empty());
    println!("discovered input-edge sharding: {edge_sharded}");
}
