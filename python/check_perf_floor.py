#!/usr/bin/env python3
"""Perf-floor gate for the search hot path (CI perf-smoke step).

Reads the freshly benchmarked ``BENCH_search.json`` at the repo root and
the committed floors in ``configs/perf_floor.json`` and fails (exit 1)
when the release-build measurements breach them:

* ``single_episodes_per_sec``  must stay ABOVE  ``floor_single_episodes_per_sec``
* ``step_median_ns``           must stay BELOW  ``max_step_median_ns``
* ``eval_median_ns``  (ledger) must stay BELOW  ``max_eval_median_ns``
* ``eval_ledger_speedup``      must stay ABOVE  ``min_eval_ledger_speedup``
* ``schedule_sim_median_ns``   must stay BELOW  ``max_schedule_sim_median_ns``
* ``parse_median_ns``          must stay BELOW  ``max_parse_median_ns``
* ``decode_median_ns``         must stay BELOW  ``max_decode_median_ns``

The floors are deliberately generous — shared CI runners are noisy and
the gate exists to catch catastrophic regressions (an accidentally
quadratic sweep, a lost cache), not 10% wobble. Debug-build reports
(``debug_build: true``) are never gated: debug builds cross-check every
ledger evaluation against the full pipeline, which makes their timings
incomparable by construction; the breach is reported as a warning only.

Beyond the floors, the report must be *schema-valid and real*: every
required key present with the right type, and the provenance field must
not carry the committed "SEED VALUES, UNMEASURED" placeholder — a
release gate that passes on numbers nobody measured is worse than no
gate at all.

Usage: python3 python/check_perf_floor.py [bench_json] [floor_json]
"""

import json
import sys

# Full BENCH_search.json schema: key -> required type. The bench writer
# (rust/src/service/throughput.rs ThroughputReport::to_json) and this
# list must move together.
REQUIRED_KEYS = {
    "bench": str,
    "budget_per_worker": (int, float),
    "workers": (int, float),
    "single_episodes_per_sec": (int, float),
    "multi_episodes_per_sec": (int, float),
    "speedup": (int, float),
    "single_evals_per_sec": (int, float),
    "multi_evals_per_sec": (int, float),
    "cache_hit_median_ns": (int, float),
    "cache_probes": (int, float),
    "step_median_ns": (int, float),
    "eval_median_ns": (int, float),
    "eval_full_median_ns": (int, float),
    "eval_ledger_speedup": (int, float),
    "eval_memo_hit_rate": (int, float),
    "ledger_reuse_rate": (int, float),
    "schedule_sim_median_ns": (int, float),
    "parse_median_ns": (int, float),
    "decode_median_ns": (int, float),
    "binary_load_speedup": (int, float),
    "rounds": (int, float),
    "steals": (int, float),
    "debug_build": bool,
    "provenance": str,
}

PLACEHOLDER_MARKER = "SEED VALUES, UNMEASURED"


def check_schema(bench, breaches):
    """Validate presence + type of every required key; return ok."""
    ok = True
    for key, want in REQUIRED_KEYS.items():
        got = bench.get(key)
        if got is None:
            breaches.append(f"schema: required key '{key}' missing from report")
            ok = False
        elif not isinstance(got, want) or isinstance(got, bool) != (want is bool):
            breaches.append(
                f"schema: key '{key}' has type {type(got).__name__}, "
                f"wanted {want.__name__ if isinstance(want, type) else 'number'}"
            )
            ok = False
    if ok:
        print(f"perf floor: schema ok ({len(REQUIRED_KEYS)} required keys present)")
    return ok


def main() -> int:
    bench_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_search.json"
    floor_path = sys.argv[2] if len(sys.argv) > 2 else "configs/perf_floor.json"
    bench = json.load(open(bench_path))
    floor = json.load(open(floor_path))

    advisory = bool(bench.get("debug_build", False))
    breaches = []

    check_schema(bench, breaches)
    provenance = bench.get("provenance", "")
    if isinstance(provenance, str) and PLACEHOLDER_MARKER in provenance:
        breaches.append(
            f"provenance carries the '{PLACEHOLDER_MARKER}' placeholder — the bench "
            "did not actually run; a release gate must never pass on seed numbers"
        )
    elif provenance:
        print(f"perf floor: provenance: {provenance}")

    def above(metric, floor_key):
        got = bench.get(metric)
        want = floor.get(floor_key)
        if got is None or want is None:
            breaches.append(f"{metric}: missing from report or floor config")
            return
        print(f"perf floor: {metric} = {got:.2f} (must be >= {want:.2f})")
        if got < want:
            breaches.append(f"{metric} {got:.2f} below the floor {want:.2f}")

    def below(metric, ceil_key):
        got = bench.get(metric)
        want = floor.get(ceil_key)
        if got is None or want is None:
            breaches.append(f"{metric}: missing from report or floor config")
            return
        print(f"perf floor: {metric} = {got:.0f} (must be <= {want:.0f})")
        if got > want:
            breaches.append(f"{metric} {got:.0f} above the ceiling {want:.0f}")

    above("single_episodes_per_sec", "floor_single_episodes_per_sec")
    below("step_median_ns", "max_step_median_ns")
    below("eval_median_ns", "max_eval_median_ns")
    above("eval_ledger_speedup", "min_eval_ledger_speedup")
    below("schedule_sim_median_ns", "max_schedule_sim_median_ns")
    below("parse_median_ns", "max_parse_median_ns")
    below("decode_median_ns", "max_decode_median_ns")

    base = bench.get("baseline_single_episodes_per_sec")
    eps = bench.get("single_episodes_per_sec")
    if base and eps:
        print(f"perf floor: {eps / base:.2f}x over the pre-overhaul baseline {base:.0f} eps/s")

    if not breaches:
        print("perf floor: all checks passed")
        return 0
    if advisory:
        for b in breaches:
            print(f"::warning title=perf floor (debug build, advisory)::{b}")
        return 0
    for b in breaches:
        print(f"::error title=perf floor::{b}")
    return 1


if __name__ == "__main__":
    sys.exit(main())
