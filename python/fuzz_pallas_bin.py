#!/usr/bin/env python3
"""Decoder corruption wall for pallas-bin (DESIGN.md §13).

Feeds systematically corrupted `.pbp` blobs to `automap decode` and
requires a clean, non-panicking rejection for every one: truncations at
stepped lengths and deterministic single-bit flips over every committed
golden. A panic (or an accidental accept of corrupt bytes) fails CI.

Usage: python3 python/fuzz_pallas_bin.py <automap-binary> [golden.pbp ...]
With no goldens named, fuzzes every configs/corpus/*.pbp.
"""

import pathlib
import subprocess
import sys
import tempfile


def run_decode(automap: str, blob: bytes, workdir: str) -> tuple[int, str]:
    path = pathlib.Path(workdir) / "fuzz.pbp"
    path.write_bytes(blob)
    proc = subprocess.run(
        [automap, "decode", str(path)],
        capture_output=True,
        text=True,
        timeout=60,
    )
    return proc.returncode, proc.stderr + proc.stdout


def fail(name: str, what: str, output: str) -> None:
    print(f"FAIL {name}: {what}")
    print(output[:2000])
    sys.exit(1)


def check_rejected(automap: str, blob: bytes, name: str, what: str, workdir: str):
    code, output = run_decode(automap, blob, workdir)
    if code == 0:
        fail(name, f"{what}: corrupt input was ACCEPTED", output)
    if "panicked" in output or "RUST_BACKTRACE" in output:
        fail(name, f"{what}: decoder PANICKED instead of erroring", output)


def main(argv) -> int:
    if not argv:
        print(__doc__)
        return 2
    automap = argv[0]
    goldens = [pathlib.Path(a) for a in argv[1:]]
    if not goldens:
        root = pathlib.Path(__file__).resolve().parent.parent
        goldens = sorted((root / "configs" / "corpus").glob("*.pbp"))
    if not goldens:
        print("fuzz_pallas_bin: no .pbp goldens found", file=sys.stderr)
        return 2

    cases = 0
    with tempfile.TemporaryDirectory() as workdir:
        for g in goldens:
            blob = g.read_bytes()
            # The pristine golden must decode cleanly.
            code, output = run_decode(automap, blob, workdir)
            if code != 0:
                fail(g.name, "pristine golden failed to decode", output)

            # Truncations: every prefix boundary near the header, then
            # stepped through the payload (all of them in Rust tests;
            # stepped here to keep the subprocess count sane).
            lengths = list(range(0, min(40, len(blob)))) + list(
                range(40, len(blob), 11)
            )
            for n in lengths:
                check_rejected(automap, blob[:n], g.name, f"truncate to {n}", workdir)
                cases += 1

            # Deterministic single-bit flips across the whole blob.
            for i in range(0, len(blob), 5):
                for bit in (0, 3, 7):
                    mutated = bytearray(blob)
                    mutated[i] ^= 1 << bit
                    check_rejected(
                        automap, bytes(mutated), g.name, f"flip byte {i} bit {bit}", workdir
                    )
                    cases += 1
    print(f"fuzz_pallas_bin: ok — {cases} corrupt blobs over {len(goldens)} goldens, "
          "all rejected cleanly")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
