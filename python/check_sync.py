#!/usr/bin/env python3
"""CI gate for replica anti-entropy over the plan log (DESIGN.md §15).

Two replicas share a sync "mailbox" directory but have separate plan
logs. The wall pins the headline guarantee: after ONE sync round each,
replica B serves replica A's entire corpus from disk — zero searches —
and the two compacted `plans.plog` files are **byte-identical**.

  1. replica A answers the corpus cold (`batch --cache-dir A`),
     populating its plan log;
  2. `automap sync` on A canonicalizes the log and publishes a snapshot
     into the shared sync dir;
  3. `automap sync` on B (empty log) pulls every plan from A's snapshot;
  4. A's and B's `plans.plog` must now be byte-identical;
  5. replica B answers the same corpus (`batch --cache-dir B`, fresh
     process): zero errors, zero searches, every response cached, one
     disk hit per unique fingerprint, and every plan document
     byte-identical to replica A's response.

Usage: python3 python/check_sync.py <automap-binary> <requests.jsonl>
Exit codes: 0 ok, 1 failures, 2 usage error.
"""

import json
import os
import re
import subprocess
import sys
import tempfile


def run(cmd, failpoints=None):
    env = dict(os.environ)
    env.pop("PALLAS_FAILPOINTS", None)
    if failpoints:
        env["PALLAS_FAILPOINTS"] = failpoints
    return subprocess.run(cmd, env=env, capture_output=True, text=True)


def load(path):
    """id -> (raw line, parsed doc, raw plan substring)."""
    out = {}
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            doc = json.loads(line)
            rid = doc.get("id")
            if rid is None:
                sys.exit(f"{path}:{ln}: response without an id")
            idx = line.find(',"plan":')
            out[rid] = (line, doc, line[idx:] if idx >= 0 else None)
    return out


def main(argv) -> int:
    if len(argv) != 2:
        print(__doc__)
        return 2
    binary, corpus = argv
    tmp = tempfile.mkdtemp(prefix="automap-sync-")
    cache_a = os.path.join(tmp, "cache-a")
    cache_b = os.path.join(tmp, "cache-b")
    sync_dir = os.path.join(tmp, "sync")
    failures = []

    # --- 1. Replica A answers the corpus cold, populating its log. ---
    resp_a = os.path.join(tmp, "a.jsonl")
    p = run([binary, "batch", corpus, "--pool", "1",
             "--cache-dir", cache_a, "--out", resp_a])
    if p.returncode != 0:
        sys.exit(f"replica A batch exited {p.returncode}:\n{p.stderr}")

    # --- 2+3. One sync round each: A publishes, B pulls everything. ---
    for name, cache in (("a", cache_a), ("b", cache_b)):
        p = run([binary, "sync", "--cache-dir", cache,
                 "--sync-dir", sync_dir, "--replica", name])
        if p.returncode != 0:
            sys.exit(f"sync on replica {name} exited {p.returncode}:\n{p.stderr}")
        if "sync:" not in p.stdout:
            failures.append(f"replica {name}: sync printed no report: {p.stdout!r}")

    # --- 4. The replicated logs must be byte-identical. ---
    log_a = open(os.path.join(cache_a, "plans.plog"), "rb").read()
    log_b = open(os.path.join(cache_b, "plans.plog"), "rb").read()
    if len(log_a) <= 32:
        failures.append("replica A's plan log is empty after the batch pass")
    if log_a != log_b:
        failures.append(
            f"plan logs differ after one sync round each "
            f"({len(log_a)} vs {len(log_b)} bytes)"
        )

    # --- 5. Replica B serves the whole corpus from disk: no searches. ---
    resp_b = os.path.join(tmp, "b.jsonl")
    p = run([binary, "batch", corpus, "--pool", "1",
             "--cache-dir", cache_b, "--out", resp_b])
    if p.returncode != 0:
        sys.exit(f"replica B batch exited {p.returncode}:\n{p.stderr}")
    m = re.search(r"(\d+) searches", p.stdout)
    if not m:
        failures.append(f"replica B batch printed no summary: {p.stdout!r}")
    elif m.group(1) != "0":
        failures.append(f"replica B ran {m.group(1)} searches; expected 0")

    a, b = load(resp_a), load(resp_b)
    if set(a) != set(b):
        sys.exit(f"request ids differ between replicas: {set(a) ^ set(b)}")
    disk_hits = 0
    for rid, (_, doc, plan_b) in sorted(b.items()):
        if doc.get("error"):
            failures.append(f"{rid}: replica B errored: {doc['error']}")
            continue
        if doc.get("cached") is not True:
            failures.append(f"{rid}: replica B ran a search (cached != true)")
        if doc.get("degraded"):
            failures.append(f"{rid}: replica B degraded a synced plan")
        if doc.get("disk") is True:
            disk_hits += 1
        plan_a = a[rid][2]
        if plan_a is None:
            failures.append(f"{rid}: replica A carried no plan")
        elif plan_a != plan_b:
            failures.append(f"{rid}: plan differs between replicas")

    unique_fps = len({d.get("fingerprint") for _, d, _ in b.values()})
    if disk_hits != unique_fps:
        failures.append(
            f"expected one disk hit per unique fingerprint "
            f"({unique_fps}), got {disk_hits}"
        )

    if failures:
        print("check_sync: FAIL")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(
        f"check_sync: ok — {len(b)} responses served by replica B with zero "
        f"searches after one sync round, logs byte-identical "
        f"({len(log_a)} bytes), {disk_hits} disk hits over "
        f"{unique_fps} unique fingerprints"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
