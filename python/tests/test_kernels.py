"""L1 correctness: Pallas kernels vs pure-jnp reference oracles.

Hypothesis sweeps shapes and seeds; assert_allclose against ref.py is
the CORE correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import fused_linear, vmem_bytes as fl_vmem
from compile.kernels.segment_sum import segment_sum, vmem_bytes as ss_vmem


def rand(key, shape):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, -1.0, 1.0)


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 3, 8, 64, 128, 200, 256]),
    k=st.sampled_from([1, 5, 40, 64]),
    n=st.sampled_from([1, 8, 64]),
    seed=st.integers(0, 2**16),
    act=st.sampled_from(["gelu", "none"]),
)
def test_fused_linear_matches_ref(m, k, n, seed, act):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    b = rand(seed + 2, (n,))
    got = fused_linear(x, w, b, act)
    want = ref.fused_linear_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    e=st.sampled_from([1, 7, 64, 256, 300, 2048]),
    h=st.sampled_from([1, 8, 64]),
    n=st.sampled_from([4, 37, 256]),
    seed=st.integers(0, 2**16),
)
def test_segment_sum_matches_ref(e, h, n, seed):
    data = rand(seed, (e, h))
    ids = jax.random.randint(jax.random.PRNGKey(seed + 9), (e,), 0, n).astype(jnp.int32)
    got = segment_sum(data, ids, n)
    want = ref.segment_sum_ref(data, ids, n)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_segment_sum_empty_segments_are_zero():
    data = jnp.ones((4, 3), jnp.float32)
    ids = jnp.array([0, 0, 1, 1], jnp.int32)
    out = segment_sum(data, ids, 5)
    np.testing.assert_allclose(out[2:], np.zeros((3, 3)))
    np.testing.assert_allclose(out[0], 2 * np.ones(3))


def test_fused_linear_grid_covers_all_rows():
    # m=200 -> block 100, two grid steps; every row must be computed.
    x = jnp.arange(200 * 4, dtype=jnp.float32).reshape(200, 4) / 100.0
    w = jnp.eye(4, dtype=jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    out = fused_linear(x, w, b, "none")
    np.testing.assert_allclose(out, x, rtol=1e-6)


def test_gelu_matches_jax_nn_closely():
    x = jnp.linspace(-4, 4, 101)
    np.testing.assert_allclose(ref.gelu(x), jax.nn.gelu(x, approximate=True), rtol=1e-5, atol=1e-6)


def test_vmem_estimates_fit_tpu_budget():
    # The ranker's largest calls must fit well under ~16MB VMEM.
    assert fl_vmem(256, 40, 64) < 1 << 22
    assert fl_vmem(2048, 64, 64) < 1 << 22
    assert ss_vmem(2048, 64, 256) < 1 << 22


@pytest.mark.parametrize("m", [1, 128, 256])
def test_fused_linear_is_jittable_and_stable(m):
    x = rand(0, (m, 40))
    w = rand(1, (40, 64))
    b = rand(2, (64,))
    a = fused_linear(x, w, b)
    bb = fused_linear(x, w, b)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
