"""Training-loop tests on a small synthetic dataset written in the same
JSON schema the rust `gen-dataset` command produces."""

import json

import numpy as np
import pytest

from compile import model as M
from compile import train as T


def synthetic_dataset(path, n_samples=6, seed=0):
    """Labels = nodes whose feature[13] (square-matrix flag) is set —
    a learnable proxy for 'attention projection weights'."""
    rng = np.random.default_rng(seed)
    samples = []
    for _ in range(n_samples):
        n_real = int(rng.integers(20, 60))
        nodes = np.zeros((M.MAX_NODES, M.NODE_FEATURES), np.float32)
        nodes[:n_real] = rng.uniform(0, 1, (n_real, M.NODE_FEATURES)).astype(np.float32)
        labels = np.zeros((M.MAX_NODES,), np.float32)
        square = rng.uniform(0, 1, n_real) > 0.7
        nodes[:n_real, 13] = square.astype(np.float32)
        labels[:n_real] = square.astype(np.float32)
        node_mask = np.zeros((M.MAX_NODES,), np.float32)
        node_mask[:n_real] = 1.0
        senders = rng.integers(0, n_real, M.MAX_EDGES).astype(np.int32)
        receivers = rng.integers(0, n_real, M.MAX_EDGES).astype(np.int32)
        edge_mask = np.zeros((M.MAX_EDGES,), np.float32)
        edge_mask[:128] = 1.0
        samples.append(
            {
                "nodes": nodes.ravel().tolist(),
                "node_mask": node_mask.tolist(),
                "senders": senders.tolist(),
                "receivers": receivers.tolist(),
                "edge_mask": edge_mask.tolist(),
                "labels": labels.tolist(),
            }
        )
    with open(path, "w") as f:
        json.dump(
            {
                "node_features": M.NODE_FEATURES,
                "max_nodes": M.MAX_NODES,
                "max_edges": M.MAX_EDGES,
                "samples": samples,
            },
            f,
        )


@pytest.fixture(scope="module")
def dataset_path(tmp_path_factory):
    p = tmp_path_factory.mktemp("data") / "dataset.json"
    synthetic_dataset(str(p))
    return str(p)


def test_load_dataset_shapes(dataset_path):
    d = T.load_dataset(dataset_path)
    assert d["nodes"].shape == (6, M.MAX_NODES, M.NODE_FEATURES)
    assert d["labels"].shape == (6, M.MAX_NODES)
    assert d["senders"].dtype == np.int32


def test_training_reduces_loss_and_learns_flag(dataset_path):
    params, history, recall = T.train(
        dataset_path, steps=60, batch_size=4, seed=0, log_every=0
    )
    assert history[-1] < history[0] * 0.9, f"loss did not drop: {history[0]} -> {history[-1]}"
    # The flag is trivially learnable: top-25 should capture most positives.
    assert recall > 0.6, f"top-25 recall too low: {recall}"


def test_save_load_roundtrip(dataset_path, tmp_path):
    params, _, _ = T.train(dataset_path, steps=5, batch_size=2, seed=1, log_every=0)
    p = tmp_path / "w.npz"
    T.save_params(params, str(p))
    loaded = T.load_params(str(p))
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(loaded[k]))


def test_adam_step_moves_params():
    params = M.init_params(0)
    state = T.adam_init(params)
    grads = {k: np.ones_like(v) for k, v in params.items()}
    new, state2 = T.adam_step(params, grads, state)
    assert state2["t"] == 1
    assert not np.allclose(np.asarray(new["w_embed"]), np.asarray(params["w_embed"]))
