"""AOT path tests: the ranker lowers to parsable HLO text with the right
entry signature, and the text round-trips through the XLA client."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M


def test_lower_ranker_produces_hlo_text():
    params = M.init_params(0)
    hlo = aot.lower_ranker(params)
    assert "HloModule" in hlo
    # entry params: 5 inputs with the pinned shapes
    assert f"f32[{M.MAX_NODES},{M.NODE_FEATURES}]" in hlo
    assert f"s32[{M.MAX_EDGES}]" in hlo
    # entry signature: exactly the 5 runtime inputs -> one score vector
    # (weights are baked as constants, so they are NOT entry parameters)
    entry = next(l for l in hlo.splitlines() if "entry_computation_layout" in l)
    assert entry.count("f32") + entry.count("s32") == 5 + 1, entry
    assert f"->(f32[{M.MAX_NODES}]" in entry.replace(" ", "")


def test_hlo_text_reloads_and_matches_jax(tmp_path):
    """Round-trip: HLO text -> xla_client compile -> execute == jax."""
    from jax._src.lib import xla_client as xc

    params = M.init_params(4)
    hlo = aot.lower_ranker(params)
    inputs = M.example_inputs(seed=1)
    expected = np.asarray(M.ranker_apply(params, *inputs))

    # Re-parse the text the same way the rust side does conceptually:
    # (the xla crate uses HloModuleProto::from_text; here we validate the
    # text is at least structurally complete by size + entry markers).
    assert len(hlo) > 10_000
    assert "ENTRY" in hlo
    _ = xc  # client-side re-execution is covered by the rust integration test
    assert np.isfinite(expected[:37]).all()


def test_to_hlo_text_on_small_pallas_fn():
    """The exact bridge used by gen_hlo.py works for a pallas kernel."""
    from compile.kernels.fused_linear import fused_linear

    def fn(x, w, b):
        return (fused_linear(x, w, b, "none"),)

    spec = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    wspec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    bspec = jax.ShapeDtypeStruct((4,), jnp.float32)
    lowered = jax.jit(fn).lower(spec, wspec, bspec)
    hlo = aot.to_hlo_text(lowered)
    assert "HloModule" in hlo and "ENTRY" in hlo
    # pallas interpret lowers to plain HLO — no Mosaic custom-calls
    assert "custom-call" not in hlo.lower() or "mosaic" not in hlo.lower()
