"""L2 correctness: ranker GNN shapes, masking invariants, and agreement
with a pure-jnp re-implementation (kernels swapped for references)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.kernels import ref


def ranker_apply_ref(params, nodes, node_mask, senders, receivers, edge_mask):
    """Same network with reference ops instead of Pallas kernels."""
    emb = ref.fused_linear_ref(nodes, params["w_embed"], params["b_embed"], "gelu")
    emb = emb * node_mask[:, None]
    for r in range(M.ROUNDS):
        sent = jnp.take(emb, senders, axis=0)
        recv = jnp.take(emb, receivers, axis=0)
        msg_in = (sent + recv) * edge_mask[:, None]
        msg = ref.fused_linear_ref(msg_in, params[f"w_msg_{r}"], params[f"b_msg_{r}"], "gelu")
        msg = msg * edge_mask[:, None]
        agg = ref.segment_sum_ref(msg, receivers, M.MAX_NODES)
        upd = ref.fused_linear_ref(emb + agg, params[f"w_node_{r}"], params[f"b_node_{r}"], "gelu")
        emb = (emb + upd) * node_mask[:, None]
    logits = ref.fused_linear_ref(emb, params["w_out"], params["b_out"], "none")[:, 0]
    return jnp.where(node_mask > 0, logits, -1e9)


def test_output_shape_and_mask():
    params = M.init_params(0)
    inputs = M.example_inputs(seed=0, n_real=37)
    scores = M.ranker_apply(params, *inputs)
    assert scores.shape == (M.MAX_NODES,)
    s = np.asarray(scores)
    assert np.isfinite(s[:37]).all()
    assert (s[37:] <= -1e8).all()


def test_kernel_and_ref_networks_agree():
    params = M.init_params(3)
    inputs = M.example_inputs(seed=5, n_real=50, e_real=200)
    got = M.ranker_apply(params, *inputs)
    want = ranker_apply_ref(params, *inputs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_padded_edges_do_not_affect_scores():
    params = M.init_params(1)
    nodes, node_mask, senders, receivers, edge_mask = M.example_inputs(seed=2, e_real=32)
    base = np.asarray(M.ranker_apply(params, nodes, node_mask, senders, receivers, edge_mask))
    # scramble padded edge endpoints — masked, so scores must not move
    senders2 = senders.at[32:].set((senders[32:] + 7) % 37)
    receivers2 = receivers.at[32:].set((receivers[32:] + 3) % 37)
    out = np.asarray(M.ranker_apply(params, nodes, node_mask, senders2, receivers2, edge_mask))
    np.testing.assert_allclose(base, out, rtol=1e-5, atol=1e-6)


def test_messages_move_information_between_nodes():
    params = M.init_params(2)
    nodes, node_mask, senders, receivers, edge_mask = M.example_inputs(seed=3, e_real=64)
    base = np.asarray(M.ranker_apply(params, nodes, node_mask, senders, receivers, edge_mask))
    # perturb node 0's features; a neighbour's score should change
    recv_of_0 = np.asarray(receivers)[:64][np.asarray(senders)[:64] == 0]
    nodes2 = nodes.at[0].add(1.0)
    out = np.asarray(M.ranker_apply(params, nodes2, node_mask, senders, receivers, edge_mask))
    if recv_of_0.size:
        j = int(recv_of_0[0])
        assert abs(out[j] - base[j]) > 1e-7, "message passing appears broken"


def test_constants_match_rust_featurizer():
    # These are pinned by rust/src/learner/features.rs; a mismatch breaks AOT.
    assert M.NODE_FEATURES == 40
    assert M.MAX_NODES == 256
    assert M.MAX_EDGES == 2048
    assert M.NUM_OP_KINDS == 26


def test_deterministic_init():
    a = M.init_params(0)
    b = M.init_params(0)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
