"""L2: the learned node ranker — an Interaction-Network-style GNN
(Battaglia et al. 2016; the paper §3 uses an Interaction Network with
Jraph) over the featurized program graph produced by
`rust/src/learner/features.rs`.

Inputs (shapes MUST match the rust featurizer — see `ranker_meta.json`):
    nodes:      f32 [MAX_NODES, NODE_FEATURES]
    node_mask:  f32 [MAX_NODES]
    senders:    i32 [MAX_EDGES]
    receivers:  i32 [MAX_EDGES]
    edge_mask:  f32 [MAX_EDGES]
Output:
    scores:     f32 [MAX_NODES]   (masked slots get -1e9)

The dense layers and the edge->node aggregation are the L1 Pallas
kernels (`kernels/fused_linear.py`, `kernels/segment_sum.py`), so the
whole ranker lowers into one HLO module for the rust runtime.
"""

import jax
import jax.numpy as jnp

from .kernels.fused_linear import fused_linear
from .kernels.segment_sum import segment_sum

# ---- constants shared with rust/src/learner/features.rs ----
NODE_FEATURES = 40
MAX_NODES = 256
MAX_EDGES = 2048
# Must equal OpKind::NUM_KINDS; checked in tests.
NUM_OP_KINDS = 26

HIDDEN = 64
ROUNDS = 2


def init_params(seed: int = 0):
    """Initialise ranker parameters (dict of f32 arrays)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 8)

    def dense(k, fan_in, fan_out):
        scale = (2.0 / fan_in) ** 0.5
        return jax.random.normal(k, (fan_in, fan_out), jnp.float32) * scale

    params = {
        "w_embed": dense(ks[0], NODE_FEATURES, HIDDEN),
        "b_embed": jnp.zeros((HIDDEN,), jnp.float32),
        "w_out": dense(ks[7], HIDDEN, 1),
        "b_out": jnp.zeros((1,), jnp.float32),
    }
    for r in range(ROUNDS):
        params[f"w_msg_{r}"] = dense(ks[1 + r], HIDDEN, HIDDEN)
        params[f"b_msg_{r}"] = jnp.zeros((HIDDEN,), jnp.float32)
        params[f"w_node_{r}"] = dense(ks[4 + r], HIDDEN, HIDDEN)
        params[f"b_node_{r}"] = jnp.zeros((HIDDEN,), jnp.float32)
    return params


def ranker_apply(params, nodes, node_mask, senders, receivers, edge_mask):
    """Score every node slot; see module docstring for shapes."""
    emb = fused_linear(nodes, params["w_embed"], params["b_embed"], "gelu")
    emb = emb * node_mask[:, None]
    for r in range(ROUNDS):
        sent = jnp.take(emb, senders, axis=0)  # [E,H]
        recv = jnp.take(emb, receivers, axis=0)
        msg_in = (sent + recv) * edge_mask[:, None]
        msg = fused_linear(msg_in, params[f"w_msg_{r}"], params[f"b_msg_{r}"], "gelu")
        msg = msg * edge_mask[:, None]
        agg = segment_sum(msg, receivers, MAX_NODES)  # [N,H]
        upd = fused_linear(emb + agg, params[f"w_node_{r}"], params[f"b_node_{r}"], "gelu")
        emb = (emb + upd) * node_mask[:, None]
    logits = fused_linear(emb, params["w_out"], params["b_out"], "none")[:, 0]
    return jnp.where(node_mask > 0, logits, -1e9)


def example_inputs(seed: int = 0, n_real: int = 37, e_real: int = 64):
    """A deterministic example input (used by AOT lowering + smoke tests)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    nodes = jax.random.uniform(k1, (MAX_NODES, NODE_FEATURES), jnp.float32)
    node_mask = (jnp.arange(MAX_NODES) < n_real).astype(jnp.float32)
    senders = jax.random.randint(k2, (MAX_EDGES,), 0, n_real).astype(jnp.int32)
    receivers = jax.random.randint(k3, (MAX_EDGES,), 0, n_real).astype(jnp.int32)
    edge_mask = (jnp.arange(MAX_EDGES) < e_real).astype(jnp.float32)
    nodes = nodes * node_mask[:, None]
    return nodes, node_mask, senders, receivers, edge_mask
