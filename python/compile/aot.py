"""AOT compile path: train (or load) the ranker, bake the weights as
constants, and lower the whole model — Pallas kernels included — to HLO
TEXT for the rust PJRT runtime.

Emit HLO text, NOT `.serialize()`: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and gen_hlo.py there).

Usage (normally via `make artifacts`):
    python -m compile.aot --out ../artifacts/ranker.hlo.txt \
        --dataset ../artifacts/dataset.json [--steps 300]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_ranker(params):
    """Close over trained params and lower the ranker to HLO text."""

    def fn(nodes, node_mask, senders, receivers, edge_mask):
        return (M.ranker_apply(params, nodes, node_mask, senders, receivers, edge_mask),)

    specs = (
        jax.ShapeDtypeStruct((M.MAX_NODES, M.NODE_FEATURES), jnp.float32),
        jax.ShapeDtypeStruct((M.MAX_NODES,), jnp.float32),
        jax.ShapeDtypeStruct((M.MAX_EDGES,), jnp.int32),
        jax.ShapeDtypeStruct((M.MAX_EDGES,), jnp.int32),
        jax.ShapeDtypeStruct((M.MAX_EDGES,), jnp.float32),
    )
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/ranker.hlo.txt")
    ap.add_argument("--dataset", default="../artifacts/dataset.json")
    ap.add_argument("--weights", default="../artifacts/ranker_weights.npz")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()

    recall = None
    if os.path.exists(args.weights) and not args.retrain:
        print(f"loading weights from {args.weights}")
        params = T.load_params(args.weights)
    elif os.path.exists(args.dataset):
        print(f"training ranker on {args.dataset}")
        params, _, recall = T.train(args.dataset, steps=args.steps, seed=args.seed)
        T.save_params(params, args.weights)
    else:
        print(
            f"WARNING: no dataset at {args.dataset} — emitting an UNTRAINED "
            "ranker (run `automap gen-dataset` first for the learned filter)"
        )
        params = M.init_params(args.seed)

    hlo = lower_ranker(params)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(hlo)
    print(f"wrote {len(hlo)} chars of HLO to {args.out}")

    # Numeric cross-check data for the rust side (tests/integration).
    inputs = M.example_inputs(seed=1)
    expected = np.asarray(M.ranker_apply(params, *inputs))
    meta = {
        "node_features": M.NODE_FEATURES,
        "max_nodes": M.MAX_NODES,
        "max_edges": M.MAX_EDGES,
        "hidden": M.HIDDEN,
        "rounds": M.ROUNDS,
        "example_seed": 1,
        "example_n_real": 37,
        "example_e_real": 64,
        "expected_scores_head": [float(x) for x in expected[:8]],
        "trained": os.path.exists(args.dataset) or os.path.exists(args.weights),
        "topk_recall": recall,
    }
    meta_path = os.path.join(os.path.dirname(args.out) or ".", "ranker_meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")

    # Also dump the example input tensors so rust can reproduce them
    # without a jax PRNG implementation.
    ex_path = os.path.join(os.path.dirname(args.out) or ".", "ranker_example.json")
    nodes, node_mask, senders, receivers, edge_mask = inputs
    with open(ex_path, "w") as f:
        json.dump(
            {
                "nodes": np.asarray(nodes).ravel().tolist(),
                "node_mask": np.asarray(node_mask).tolist(),
                "senders": np.asarray(senders).tolist(),
                "receivers": np.asarray(receivers).tolist(),
                "edge_mask": np.asarray(edge_mask).tolist(),
                "expected_scores": expected.tolist(),
            },
            f,
        )
    print(f"wrote {ex_path}")


if __name__ == "__main__":
    main()
