"""L1 Pallas kernel: fused dense layer  y = act(x @ w + b).

TPU mapping (DESIGN.md §6 Hardware adaptation):
  * grid over row blocks of x; each step holds one [BM, K] x-tile, the
    full [K, N] weight panel, and the [BM, N] output tile in VMEM —
    sized so BM=128 keeps the working set well under the ~16 MB VMEM
    budget for the ranker's K,N <= 2048.
  * the matmul maps onto the MXU systolic array; bias add + GELU run in
    the epilogue on the VPU so the activation never round-trips HBM
    (this fusion is the point of the kernel).

`interpret=True` everywhere in this repo: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the rust
runtime executes (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "gelu":
        y = ref.gelu(y)
    o_ref[...] = y


def _pick_block(m, target=128):
    """Largest divisor of m that is <= target (rows per grid step)."""
    bm = min(m, target)
    while m % bm != 0:
        bm -= 1
    return bm


def _pallas_fused_linear(x, w, b, activation):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm = _pick_block(m)
    return pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


# Pallas kernels are forward-only; build-time training differentiates
# through the ranker, so the backward pass is defined against the
# (numerically identical) pure-jnp reference.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation="gelu"):
    """y = act(x @ w + b) as a Pallas kernel. x: [M,K], w: [K,N], b: [N]."""
    return _pallas_fused_linear(x, w, b, activation)


def _fl_fwd(x, w, b, activation):
    return _pallas_fused_linear(x, w, b, activation), (x, w, b)


def _fl_bwd(activation, res, g):
    x, w, b = res
    _, vjp = jax.vjp(lambda x_, w_, b_: ref.fused_linear_ref(x_, w_, b_, activation), x, w, b)
    return vjp(g)


fused_linear.defvjp(_fl_fwd, _fl_bwd)


def vmem_bytes(m, k, n, target=128):
    """Estimated per-step VMEM footprint (f32), for DESIGN/EXPERIMENTS."""
    bm = _pick_block(m, target)
    return 4 * (bm * k + k * n + n + bm * n)
