"""L1 Pallas kernel: segment-sum (edge -> node aggregation).

TPU mapping (DESIGN.md §6): scatter-adds are serial on TPU, so the
kernel maps aggregation onto the MXU instead — each grid step builds a
[BE, N] one-hot matrix from the ids block and accumulates
`one_hot.T @ data_block` into the full [N, H] output resident in VMEM.
The accumulator pattern relies on the TPU grid being sequential
(initialise at step 0, accumulate afterwards), which interpret mode
preserves.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, data_ref, o_ref, *, num_segments):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]
    data = data_ref[...]
    seg_iota = jax.lax.broadcasted_iota(jnp.int32, (1, num_segments), 1)
    one_hot = (ids[:, None] == seg_iota).astype(data.dtype)  # [BE, N]
    o_ref[...] += jnp.dot(one_hot.T, data, preferred_element_type=jnp.float32)


def _pick_block(e, target=256):
    be = min(e, target)
    while e % be != 0:
        be -= 1
    return be


def _pallas_segment_sum(data, ids, num_segments):
    e, hdim = data.shape
    assert ids.shape == (e,)
    be = _pick_block(e)
    return pl.pallas_call(
        functools.partial(_kernel, num_segments=num_segments),
        grid=(e // be,),
        in_specs=[
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be, hdim), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, hdim), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, hdim), jnp.float32),
        interpret=True,
    )(ids, data)


# Forward runs the Pallas kernel; backward is the exact adjoint
# (gather rows of the cotangent by segment id).
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def segment_sum(data, ids, num_segments):
    """Scatter-add rows: data [E,H], ids int32 [E] -> [num_segments, H]."""
    return _pallas_segment_sum(data, ids, num_segments)


def _ss_fwd(data, ids, num_segments):
    return _pallas_segment_sum(data, ids, num_segments), ids


def _ss_bwd(num_segments, ids, g):
    import numpy as np

    d_data = jnp.take(g, ids, axis=0)
    d_ids = np.zeros(ids.shape, dtype=jax.dtypes.float0)
    return (d_data, d_ids)


segment_sum.defvjp(_ss_fwd, _ss_bwd)


def vmem_bytes(e, hdim, num_segments, target=256):
    """Estimated per-step VMEM footprint (f32)."""
    be = _pick_block(e, target)
    return 4 * (be + be * hdim + be * num_segments + num_segments * hdim)
