"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package must match its reference here to within
float32 tolerance; `python/tests/test_kernels.py` sweeps shapes and
seeds with hypothesis to enforce it.
"""

import jax.numpy as jnp


def gelu(x):
    """Tanh-approximation GELU (matches the rust IR builder's gelu)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def fused_linear_ref(x, w, b, activation="gelu"):
    """y = act(x @ w + b).

    x: [M, K], w: [K, N], b: [N] -> [M, N]
    """
    y = x @ w + b[None, :]
    if activation == "gelu":
        y = gelu(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation}")
    return y


def segment_sum_ref(data, ids, num_segments):
    """Scatter-add rows of data into segments.

    data: [E, H], ids: int32 [E] -> [num_segments, H]
    """
    return jnp.zeros((num_segments, data.shape[1]), data.dtype).at[ids].add(data)
