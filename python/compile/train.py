"""Build-time training of the ranker (paper §3: trained to imitate the
highest-scoring strategy over a corpus of transformer variants).

Consumes `artifacts/dataset.json` produced by `automap gen-dataset`
(the rust cost model + greedy exhaustive strategy labeller). Loss is
masked binary cross-entropy per node; optimiser is a hand-rolled Adam
(optax is not installed in this image — DESIGN.md §3).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .model import MAX_EDGES, MAX_NODES, NODE_FEATURES, init_params, ranker_apply


def load_dataset(path):
    """Load the rust-generated dataset into stacked numpy arrays."""
    with open(path) as f:
        d = json.load(f)
    assert d["node_features"] == NODE_FEATURES, "featurizer out of sync"
    assert d["max_nodes"] == MAX_NODES and d["max_edges"] == MAX_EDGES
    samples = d["samples"]

    def stack(key, dtype, shape):
        return np.asarray(
            [np.asarray(s[key], dtype=dtype).reshape(shape) for s in samples]
        )

    return {
        "nodes": stack("nodes", np.float32, (MAX_NODES, NODE_FEATURES)),
        "node_mask": stack("node_mask", np.float32, (MAX_NODES,)),
        "senders": stack("senders", np.int32, (MAX_EDGES,)),
        "receivers": stack("receivers", np.int32, (MAX_EDGES,)),
        "edge_mask": stack("edge_mask", np.float32, (MAX_EDGES,)),
        "labels": stack("labels", np.float32, (MAX_NODES,)),
    }


def bce_loss(params, batch):
    """Masked binary cross-entropy over node slots."""
    def one(nodes, node_mask, senders, receivers, edge_mask, labels):
        logits = ranker_apply(params, nodes, node_mask, senders, receivers, edge_mask)
        z = jnp.clip(logits, -30.0, 30.0)
        per = jnp.maximum(z, 0.0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
        return jnp.sum(per * node_mask) / jnp.maximum(jnp.sum(node_mask), 1.0)

    losses = jax.vmap(one)(
        batch["nodes"],
        batch["node_mask"],
        batch["senders"],
        batch["receivers"],
        batch["edge_mask"],
        batch["labels"],
    )
    return jnp.mean(losses)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vhat = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new, {"m": m, "v": v, "t": t}


def topk_recall(params, data, k=25):
    """Fraction of positive labels captured in the top-k scores (the
    quantity that matters: does the filter keep the Megatron args?)."""
    hits, total = 0.0, 0.0
    for i in range(data["nodes"].shape[0]):
        scores = np.asarray(
            ranker_apply(
                params,
                data["nodes"][i],
                data["node_mask"][i],
                data["senders"][i],
                data["receivers"][i],
                data["edge_mask"][i],
            )
        )
        top = set(np.argsort(-scores)[:k].tolist())
        pos = set(np.nonzero(data["labels"][i] > 0)[0].tolist())
        if pos:
            hits += len(pos & top)
            total += len(pos)
    return hits / max(total, 1.0)


def train(dataset_path, steps=300, batch_size=8, seed=0, lr=3e-3, log_every=50):
    data = load_dataset(dataset_path)
    n = data["nodes"].shape[0]
    params = init_params(seed)
    state = adam_init(params)
    loss_grad = jax.jit(jax.value_and_grad(bce_loss))
    rng = np.random.default_rng(seed)
    history = []
    for step in range(steps):
        idx = rng.integers(0, n, size=min(batch_size, n))
        batch = {k: v[idx] for k, v in data.items()}
        loss, grads = loss_grad(params, batch)
        params, state = adam_step(params, grads, state, lr=lr)
        history.append(float(loss))
        if log_every and step % log_every == 0:
            print(f"train step {step}: loss={float(loss):.4f}")
    recall = topk_recall(params, data)
    print(f"final loss={history[-1]:.4f} top-25 recall={recall:.3f}")
    return params, history, recall


def save_params(params, path):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path):
    loaded = np.load(path)
    return {k: jnp.asarray(loaded[k]) for k in loaded.files}
