#!/usr/bin/env python3
"""CI gate for the persistent plan-cache tier (DESIGN.md §13).

Compares two `automap batch` response files produced by two *separate
processes* sharing one `--cache-dir`:

  pass 1 (cold log)  — populates the disk tier while searching;
  pass 2 (fresh process, warm log) — must answer every request from the
  persistent tier: zero errors, every response `"cached":true`, at least
  one `"disk":true` hit, and the plan document byte-identical to pass
  1's for every request id.

Usage: python3 python/check_disk_tier.py pass1.jsonl pass2.jsonl
"""

import json
import sys


def load(path: str) -> dict:
    """id -> (raw line, parsed doc, raw plan substring)."""
    out = {}
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            doc = json.loads(line)
            rid = doc.get("id")
            if rid is None:
                sys.exit(f"{path}:{ln}: response without an id")
            # The plan document is spliced in verbatim by the service;
            # compare the raw bytes, not a re-serialisation.
            idx = line.find(',"plan":')
            plan_raw = line[idx:] if idx >= 0 else None
            out[rid] = (line, doc, plan_raw)
    return out


def main(argv) -> int:
    if len(argv) != 2:
        print(__doc__)
        return 2
    pass1, pass2 = load(argv[0]), load(argv[1])
    if set(pass1) != set(pass2):
        sys.exit(f"request ids differ between passes: {set(pass1) ^ set(pass2)}")
    if not pass1:
        sys.exit("no responses to compare")

    failures = []
    disk_hits = 0
    for rid, (_, doc2, plan2) in sorted(pass2.items()):
        if doc2.get("error"):
            failures.append(f"{rid}: pass 2 errored: {doc2['error']}")
            continue
        if doc2.get("cached") is not True:
            failures.append(f"{rid}: pass 2 ran a search (cached != true)")
        if doc2.get("disk") is True:
            disk_hits += 1
        plan1 = pass1[rid][2]
        if plan1 is None:
            failures.append(f"{rid}: pass 1 carried no plan")
        elif plan1 != plan2:
            failures.append(f"{rid}: plan document differs between passes")

    # Every unique fingerprint is absent from pass 2's fresh memory
    # tier, so each one must be served from disk exactly once (repeat
    # ids of the same fingerprint then hit the promoted memory entry).
    unique_fps = len({d.get("fingerprint") for _, d, _ in pass2.values()})
    if disk_hits < 1:
        failures.append("pass 2 reported no disk-tier hits at all")
    elif disk_hits != unique_fps:
        failures.append(
            f"expected one disk hit per unique fingerprint "
            f"({unique_fps}), got {disk_hits}"
        )

    if failures:
        print("check_disk_tier: FAIL")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(
        f"check_disk_tier: ok — {len(pass2)} responses, {disk_hits} disk-tier "
        f"hits over {unique_fps} unique fingerprints, plans byte-identical"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
