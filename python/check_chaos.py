#!/usr/bin/env python3
"""Chaos smoke wall for the fault-tolerant serving layer (DESIGN.md §14).

Drives `automap batch` over the smoke corpus four times:

  1. fault-free, twice      — both passes must exit 0 with zero errors,
                              carry NO degraded/fallback markers, and be
                              byte-identical per request id (the
                              determinism contract);
  2. worker panic storm     — PALLAS_FAILPOINTS=worker.panic=0.5 plus a
                              1 ms deadline: the run must still exit 0,
                              answer EVERY request with a plan, and
                              label at least one response degraded;
  3. disk fault storm       — read+write failpoints against a throwaway
                              --cache-dir: faults degrade to misses and
                              uncompacted logs, never to failures;
  4. slow rounds + deadline — search.slow_round=1.0 with --deadline-ms:
                              every cold search must stop at the gate
                              and come back `"degraded":"deadline"`;
  5. sync failpoint storm   — corrupt frames, dropped connections, and
                              torn snapshot publishes over `automap sync`
                              (DESIGN.md §15): storm rounds must exit 0,
                              and once the faults lift the two replica
                              logs must still converge byte-identically.

Usage: python3 python/check_chaos.py <automap-binary> <requests.jsonl>
Exit codes: 0 ok, 1 failures, 2 usage error.
"""

import json
import os
import subprocess
import sys
import tempfile


def run_batch(binary, corpus, out, failpoints=None, flags=()):
    """Run one `automap batch` pass, returning the CompletedProcess."""
    env = dict(os.environ)
    env.pop("PALLAS_FAILPOINTS", None)
    if failpoints:
        env["PALLAS_FAILPOINTS"] = failpoints
    cmd = [binary, "batch", corpus, "--pool", "1", "--out", out, *flags]
    return subprocess.run(cmd, env=env, capture_output=True, text=True)


def load(path):
    """id -> (raw line, parsed doc)."""
    out = {}
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            doc = json.loads(line)
            rid = doc.get("id")
            if rid is None:
                sys.exit(f"{path}:{ln}: response without an id")
            out[rid] = (line, doc)
    return out


def check_all_answered(name, responses, expected_ids, failures):
    """Every corpus id present, zero errors, every response has a plan."""
    if set(responses) != expected_ids:
        failures.append(f"{name}: ids differ: {set(responses) ^ expected_ids}")
        return
    for rid, (_, doc) in sorted(responses.items()):
        if doc.get("error"):
            failures.append(f"{name}: {rid} errored: {doc['error']}")
        elif "plan" not in doc:
            failures.append(f"{name}: {rid} answered without a plan")


def main(argv) -> int:
    if len(argv) != 2:
        print(__doc__)
        return 2
    binary, corpus = argv
    with open(corpus) as f:
        expected_ids = {
            json.loads(line)["id"] for line in f if line.strip()
        }
    if not expected_ids:
        sys.exit(f"{corpus}: no requests")

    failures = []
    tmp = tempfile.mkdtemp(prefix="automap-chaos-")

    # --- 1. The determinism contract: fault-free, twice, byte-equal. ---
    passes = []
    for i in (1, 2):
        out = os.path.join(tmp, f"clean{i}.jsonl")
        p = run_batch(binary, corpus, out)
        if p.returncode != 0:
            sys.exit(f"clean pass {i} exited {p.returncode}:\n{p.stderr}")
        passes.append(load(out))
    check_all_answered("clean", passes[0], expected_ids, failures)
    for rid in sorted(expected_ids):
        line1, line2 = passes[0][rid][0], passes[1][rid][0]
        if line1 != line2:
            failures.append(f"clean: {rid} differs between fault-free re-runs")
        for key in ('"degraded"', '"fallback"', '"worker_panics"'):
            if key in line1:
                failures.append(f"clean: {rid} carries {key} with no faults armed")

    # --- 2. Panic storm under a 1 ms deadline: degraded, never dropped. ---
    out = os.path.join(tmp, "panic.jsonl")
    p = run_batch(
        binary, corpus, out,
        failpoints="worker.panic=0.5@11",
        flags=("--deadline-ms", "1"),
    )
    if p.returncode != 0:
        failures.append(f"panic storm exited {p.returncode}:\n{p.stderr}")
    else:
        responses = load(out)
        check_all_answered("panic", responses, expected_ids, failures)
        degraded = sum(
            1 for _, doc in responses.values() if doc.get("degraded")
        )
        if degraded == 0:
            failures.append("panic: no response was labeled degraded")

    # --- 3. Disk fault storm against a throwaway cache dir. ---
    out = os.path.join(tmp, "disk.jsonl")
    p = run_batch(
        binary, corpus, out,
        failpoints="disk.read_err=0.5@7,disk.write_err=0.5@8",
        flags=("--cache-dir", os.path.join(tmp, "plan-cache")),
    )
    if p.returncode != 0:
        failures.append(f"disk storm exited {p.returncode}:\n{p.stderr}")
    else:
        check_all_answered("disk", load(out), expected_ids, failures)

    # --- 4. Slow rounds against a deadline: anytime plans, labeled. ---
    out = os.path.join(tmp, "slow.jsonl")
    p = run_batch(
        binary, corpus, out,
        failpoints="search.slow_round=1.0@3",
        flags=("--deadline-ms", "10"),
    )
    if p.returncode != 0:
        failures.append(f"slow-round storm exited {p.returncode}:\n{p.stderr}")
    else:
        responses = load(out)
        check_all_answered("slow", responses, expected_ids, failures)
        hits = sum(
            1
            for _, doc in responses.values()
            if doc.get("degraded") == "deadline"
        )
        if hits == 0:
            failures.append('slow: no response was labeled "degraded":"deadline"')

    # --- 5. Sync failpoint storm: degraded rounds, then convergence. ---
    sync_storm = (
        "sync.frame_corrupt=0.5@21,sync.conn_drop=0.3@22,sync.partial_write=0.3@23"
    )
    cache_a = os.path.join(tmp, "sync-cache-a")
    cache_b = os.path.join(tmp, "sync-cache-b")
    sync_dir = os.path.join(tmp, "sync-mailbox")
    out = os.path.join(tmp, "sync-seed.jsonl")
    p = run_batch(binary, corpus, out, flags=("--cache-dir", cache_a))
    if p.returncode != 0:
        failures.append(f"sync seed batch exited {p.returncode}:\n{p.stderr}")
    else:
        def run_sync(name, cache, failpoints=None):
            env = dict(os.environ)
            env.pop("PALLAS_FAILPOINTS", None)
            if failpoints:
                env["PALLAS_FAILPOINTS"] = failpoints
            return subprocess.run(
                [binary, "sync", "--cache-dir", cache,
                 "--sync-dir", sync_dir, "--replica", name],
                env=env, capture_output=True, text=True,
            )

        # Storm rounds: faults quarantine and retry, they never fail.
        for name, cache in (("a", cache_a), ("b", cache_b), ("b", cache_b)):
            p = run_sync(name, cache, failpoints=sync_storm)
            if p.returncode != 0:
                failures.append(
                    f"sync storm on {name} exited {p.returncode}:\n{p.stderr}"
                )
        # Faults lifted: one clean round each must converge exactly.
        for name, cache in (("a", cache_a), ("b", cache_b), ("a", cache_a)):
            p = run_sync(name, cache)
            if p.returncode != 0:
                failures.append(
                    f"clean sync on {name} exited {p.returncode}:\n{p.stderr}"
                )
        log_a = open(os.path.join(cache_a, "plans.plog"), "rb").read()
        log_b = open(os.path.join(cache_b, "plans.plog"), "rb").read()
        if len(log_a) <= 32:
            failures.append("sync storm: replica A's plan log is empty")
        if log_a != log_b:
            failures.append(
                f"sync storm: logs differ after clean rounds "
                f"({len(log_a)} vs {len(log_b)} bytes)"
            )

    if failures:
        print("check_chaos: FAIL")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(
        f"check_chaos: ok — {len(expected_ids)} requests answered under every "
        f"storm, fault-free passes byte-identical, degraded responses labeled, "
        f"replica logs converged after the sync storm"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
