#!/usr/bin/env python3
"""Metrics-snapshot schema gate (CI batch-smoke step).

Diffs a ``--metrics-out`` snapshot (from ``automap batch ... --metrics-out``
or ``serve``) against the committed key sets in
``configs/metrics_schema.json``:

* every counter / gauge / histogram name in the schema must be present
  in the snapshot (``register_service_metrics`` pre-registers them all,
  so a missing key means the registration list regressed);
* the snapshot must not carry names absent from the schema (a new
  metric landed in rust/src/obs/metrics.rs without updating the schema
  — dashboards keyed off the schema would silently miss it);
* every histogram must carry the full field set
  (count/sum/min/max/mean/p50/p90/p99);
* the snapshot's ``requests`` telemetry section must be a list whose
  entries carry id / fingerprint / latency_ms / timeline.

Usage: python3 python/check_metrics_schema.py snapshot.json [schema.json]
"""

import json
import sys


def diff(kind, got, want, errors):
    got, want = set(got), set(want)
    for name in sorted(want - got):
        errors.append(f"{kind}: '{name}' required by the schema but missing from the snapshot")
    for name in sorted(got - want):
        errors.append(f"{kind}: '{name}' in the snapshot but not in configs/metrics_schema.json")


def main() -> int:
    if len(sys.argv) < 2:
        print("usage: check_metrics_schema.py snapshot.json [schema.json]")
        return 2
    snap = json.load(open(sys.argv[1]))
    schema_path = sys.argv[2] if len(sys.argv) > 2 else "configs/metrics_schema.json"
    schema = json.load(open(schema_path))

    errors = []
    for kind in ("counters", "gauges", "histograms"):
        section = snap.get(kind)
        if not isinstance(section, dict):
            errors.append(f"{kind}: section missing from the snapshot")
            continue
        diff(kind, section.keys(), schema[kind], errors)

    hist_fields = set(schema["histogram_fields"])
    for name, h in (snap.get("histograms") or {}).items():
        if not isinstance(h, dict) or set(h.keys()) != hist_fields:
            got = sorted(h.keys()) if isinstance(h, dict) else type(h).__name__
            errors.append(f"histogram '{name}': fields {got}, wanted {sorted(hist_fields)}")

    requests = snap.get("requests")
    if not isinstance(requests, list):
        errors.append("requests: per-request telemetry section missing or not a list")
    else:
        for i, r in enumerate(requests):
            missing = [k for k in ("id", "fingerprint", "latency_ms", "timeline") if k not in r]
            if missing:
                errors.append(f"requests[{i}]: missing fields {missing}")

    if errors:
        for e in errors:
            print(f"::error title=metrics schema::{e}")
        return 1
    n_req = len(requests) if isinstance(requests, list) else 0
    print(
        f"metrics schema: ok — {len(snap.get('counters', {}))} counters, "
        f"{len(snap.get('gauges', {}))} gauges, {len(snap.get('histograms', {}))} histograms, "
        f"{n_req} request timelines"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
