#!/usr/bin/env python3
"""Reference encoder for the pallas-bin program format (DESIGN.md §13).

Parses the textual IR (DESIGN.md §10) and emits the exact bytes
`rust/src/ir/binary.rs::encode_program` produces — byte for byte. Used
to generate the committed `configs/corpus/*.pbp` goldens; CI proves the
equivalence each run by re-encoding every corpus program with the Rust
`automap encode` and `cmp`-ing against these goldens.

Usage:
    python3 python/pallas_bin.py file.pir [...]      # write siblings .pbp
    python3 python/pallas_bin.py --check file.pir .. # verify, write nothing
With no files, processes every configs/corpus/*.pir.
"""

import pathlib
import struct
import sys

MAGIC = b"PLSB"
FORMAT_VERSION = 1
KIND_PROGRAM = 1

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
U64 = 0xFFFFFFFFFFFFFFFF

DTYPE_TAGS = {"f32": 0, "bf16": 1, "i32": 2, "i1": 3}
ARG_KIND_TAGS = {"param": 0, "opt_state": 1, "input": 2, "const": 3}
CMP_TAGS = {"Lt": 0, "Le": 1, "Gt": 2, "Ge": 3, "Eq": 4, "Ne": 5}
# Mirrors OpKind::kind_id (rust/src/ir/op.rs).
OP_TAGS = {
    "const": 0, "iota": 1, "add": 2, "sub": 3, "mul": 4, "div": 5,
    "max": 6, "min": 7, "neg": 8, "exp": 9, "log": 10, "tanh": 11,
    "rsqrt": 12, "sqrt": 13, "abs": 14, "compare": 15, "select": 16,
    "convert": 17, "dot": 18, "reduce_sum": 19, "reduce_max": 20,
    "broadcast_in_dim": 21, "reshape": 22, "transpose": 23,
    "gather": 24, "segment_sum": 25,
}
SIMPLE_OPS = {
    "add", "sub", "mul", "div", "max", "min", "neg", "exp", "log",
    "tanh", "rsqrt", "sqrt", "abs", "select", "convert", "reshape",
    "gather",
}


def fnv64(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & U64
    return h


class ParseError(Exception):
    pass


class Parser:
    """Cursor parser mirroring rust/src/ir/parser.rs (accepting subset)."""

    def __init__(self, src: str):
        self.src = src
        self.pos = 0

    def rest(self) -> str:
        return self.src[self.pos:]

    def peek(self):
        return self.src[self.pos] if self.pos < len(self.src) else None

    def bump(self):
        c = self.peek()
        if c is not None:
            self.pos += 1
        return c

    def fail(self, msg: str):
        line = self.src.count("\n", 0, self.pos) + 1
        raise ParseError(f"line {line}: {msg}")

    def skip_ws(self):
        while self.peek() in (" ", "\t", "\n", "\r"):
            self.bump()

    def skip_inline_ws(self):
        while self.peek() in (" ", "\t"):
            self.bump()

    def eat(self, c: str) -> bool:
        if self.peek() == c:
            self.bump()
            return True
        return False

    def expect(self, c: str):
        if not self.eat(c):
            self.fail(f"expected '{c}', found {self.rest()[:12]!r}")

    def eat_str(self, s: str) -> bool:
        if self.rest().startswith(s):
            self.pos += len(s)
            return True
        return False

    def expect_kw(self, kw: str):
        if not self.eat_str(kw):
            self.fail(f"expected '{kw}', found {self.rest()[:12]!r}")

    def ident(self) -> str:
        c = self.peek()
        if c is None or not (c.isascii() and (c.isalpha() or c == "_")):
            self.fail(f"expected identifier, found {self.rest()[:12]!r}")
        out = []
        while True:
            c = self.peek()
            if c is not None and c.isascii() and (c.isalnum() or c in "_./-"):
                out.append(c)
                self.bump()
            else:
                return "".join(out)

    def uint(self) -> int:
        c = self.peek()
        if c is None or not c.isdigit():
            self.fail(f"expected integer, found {self.rest()[:12]!r}")
        n = 0
        while (c := self.peek()) is not None and c.isdigit():
            n = n * 10 + int(c)
            self.bump()
        return n

    def int_(self) -> int:
        neg = self.eat("-")
        n = self.uint()
        return -n if neg else n

    def float_(self) -> float:
        out = []
        while (c := self.peek()) is not None and (
            (c.isascii() and c.isalnum()) or c in "+-."
        ):
            out.append(c)
            self.bump()
        try:
            return float("".join(out))
        except ValueError:
            self.fail(f"expected float literal, found {''.join(out)!r}")

    def quoted(self) -> str:
        self.expect('"')
        out = []
        escapes = {'"': '"', "\\": "\\", "n": "\n", "t": "\t", "r": "\r"}
        while True:
            c = self.bump()
            if c is None or c == "\n":
                self.fail("unterminated string literal")
            if c == '"':
                return "".join(out)
            if c == "\\":
                e = self.bump()
                if e not in escapes:
                    self.fail(f"bad escape \\{e}")
                out.append(escapes[e])
            else:
                out.append(c)

    def uint_list(self):
        self.expect("[")
        xs = []
        self.skip_inline_ws()
        if self.eat("]"):
            return xs
        while True:
            xs.append(self.uint())
            self.skip_inline_ws()
            if self.eat(","):
                self.skip_inline_ws()
            else:
                self.expect("]")
                return xs

    def tensor_type(self):
        self.expect_kw("tensor")
        self.expect("<")
        body = []
        while True:
            c = self.peek()
            if c is None or c == "\n":
                self.fail("unterminated tensor type")
            self.bump()
            if c == ">":
                break
            body.append(c)
        pieces = "".join(body).split("x")
        dtype, dims_s = pieces[-1], pieces[:-1]
        if dtype not in DTYPE_TAGS:
            self.fail(f"bad dtype '{dtype}'")
        dims = []
        for d in dims_s:
            n = int(d)
            if n <= 0:
                self.fail(f"non-positive dimension {n}")
            dims.append(n)
        return (DTYPE_TAGS[dtype], dims)


class Program:
    def __init__(self, name: str):
        self.name = name
        self.scopes = [""]  # ScopeId 0 is the root
        self.args = []      # (name, kind_tag, scope_id, ty)
        self.nodes = []     # (op_tag, attrs_bytes, inputs, ty, scope_id)
        self.outputs = []

    def intern_scope(self, path: str) -> int:
        if path in self.scopes:
            return self.scopes.index(path)
        self.scopes.append(path)
        return len(self.scopes) - 1


def parse_program(src: str) -> Program:
    p = Parser(src)
    p.skip_ws()
    p.expect_kw("func")
    p.skip_inline_ws()
    p.expect("@")
    prog = Program(p.ident())
    p.skip_inline_ws()
    p.expect("(")
    p.skip_ws()
    if p.peek() != ")":
        while True:
            parse_arg(p, prog)
            p.skip_ws()
            if p.eat(","):
                p.skip_ws()
            else:
                break
    p.expect(")")
    p.skip_ws()
    p.expect_kw("->")
    p.skip_ws()
    p.expect("(")
    p.skip_ws()
    if p.peek() != ")":
        while True:
            p.tensor_type()  # declared result types: checked by Rust, skipped here
            p.skip_ws()
            if p.eat(","):
                p.skip_ws()
            else:
                break
    p.expect(")")
    p.skip_ws()
    p.expect("{")
    while True:
        p.skip_ws()
        if p.eat_str("return"):
            break
        if p.peek() == "%":
            parse_node(p, prog)
        else:
            p.fail(f"expected node or return, found {p.rest()[:12]!r}")
    p.skip_inline_ws()
    while p.peek() == "%":
        prog.outputs.append(value_ref(p, prog))
        p.skip_inline_ws()
        if p.eat(","):
            p.skip_inline_ws()
        else:
            break
    p.skip_ws()
    p.expect("}")
    p.skip_ws()
    if p.peek() is not None:
        p.fail("unexpected input after '}'")
    return prog


def value_ref(p: Parser, prog: Program) -> int:
    p.expect("%")
    c = p.peek()
    if c is not None and c.isdigit():
        n = p.uint()
        if n >= len(prog.nodes):
            p.fail(f"%{n} referenced before its definition")
        return len(prog.args) + n
    if not p.eat_str("arg"):
        p.fail("expected %N or %argN")
    n = p.uint()
    if n >= len(prog.args):
        p.fail(f"%arg{n} out of range")
    return n


def parse_arg(p: Parser, prog: Program):
    p.expect("%")
    if not p.eat_str("arg"):
        p.fail("expected %argN")
    n = p.uint()
    if n != len(prog.args):
        p.fail(f"arguments out of order: expected %arg{len(prog.args)}")
    p.skip_inline_ws()
    p.expect(":")
    p.skip_inline_ws()
    ty = p.tensor_type()
    p.skip_inline_ws()
    p.expect("{")
    p.skip_inline_ws()
    kind = p.ident()
    if kind not in ARG_KIND_TAGS:
        p.fail(f"bad arg kind '{kind}'")
    name = None
    scope = None
    p.skip_inline_ws()
    while p.eat(","):
        p.skip_inline_ws()
        key = p.ident()
        p.skip_inline_ws()
        p.expect("=")
        p.skip_inline_ws()
        val = p.quoted()
        if key == "name" and name is None:
            name = val
        elif key == "scope" and scope is None:
            scope = val
        else:
            p.fail(f"bad or duplicate arg attribute '{key}'")
        p.skip_inline_ws()
    p.expect("}")
    scope_id = 0 if scope is None else prog.intern_scope(scope)
    if name is None:
        name = f"arg{n}"
    prog.args.append((name, ARG_KIND_TAGS[kind], scope_id, ty))


def attr_open(p: Parser, key: str):
    p.skip_inline_ws()
    p.expect("{")
    p.skip_inline_ws()
    p.expect_kw(key)
    p.skip_inline_ws()
    p.expect("=")
    p.skip_inline_ws()


def attr_close(p: Parser):
    p.skip_inline_ws()
    p.expect("}")


def op_attrs(p: Parser, opname: str) -> bytes:
    """Consume the op's attribute block and return its encoded bytes
    (what binary.rs::encode_op writes after the tag)."""
    if opname in SIMPLE_OPS:
        if p.peek() == "{":
            p.fail(f"op '{opname}' takes no attributes")
        return b""
    if opname == "const":
        attr_open(p, "value")
        v = p.float_()
        attr_close(p)
        return struct.pack("<d", v)
    if opname == "iota":
        attr_open(p, "dim")
        d = p.uint()
        attr_close(p)
        return struct.pack("<Q", d)
    if opname == "compare":
        attr_open(p, "dir")
        d = p.ident()
        if d not in CMP_TAGS:
            p.fail(f"bad compare dir '{d}'")
        attr_close(p)
        return struct.pack("<B", CMP_TAGS[d])
    if opname == "dot":
        attr_open(p, "batch")
        lhs_b = p.uint_list()
        p.expect("x")
        rhs_b = p.uint_list()
        p.skip_inline_ws()
        p.expect(",")
        p.skip_inline_ws()
        p.expect_kw("contract")
        p.skip_inline_ws()
        p.expect("=")
        p.skip_inline_ws()
        lhs_c = p.uint_list()
        p.expect("x")
        rhs_c = p.uint_list()
        attr_close(p)
        return b"".join(enc_usizes(xs) for xs in (lhs_b, rhs_b, lhs_c, rhs_c))
    if opname in ("reduce_sum", "reduce_max"):
        attr_open(p, "dims")
        dims = p.uint_list()
        attr_close(p)
        return enc_usizes(dims)
    if opname == "broadcast_in_dim":
        attr_open(p, "broadcast_dims")
        dims = p.uint_list()
        attr_close(p)
        return enc_usizes(dims)
    if opname == "transpose":
        attr_open(p, "perm")
        perm = p.uint_list()
        attr_close(p)
        return enc_usizes(perm)
    if opname == "segment_sum":
        attr_open(p, "num")
        num = p.int_()
        attr_close(p)
        return struct.pack("<q", num)
    p.fail(f"unknown op '{opname}'")


def parse_node(p: Parser, prog: Program):
    p.expect("%")
    n = p.uint()
    if n != len(prog.nodes):
        p.fail(f"nodes out of order: expected %{len(prog.nodes)}")
    p.skip_inline_ws()
    p.expect("=")
    p.skip_inline_ws()
    opname = p.ident()
    if opname not in OP_TAGS:
        p.fail(f"unknown op '{opname}'")
    inputs = []
    p.skip_inline_ws()
    while p.peek() == "%":
        inputs.append(value_ref(p, prog))
        p.skip_inline_ws()
        if p.eat(","):
            p.skip_inline_ws()
            if p.peek() != "%":
                p.fail("expected value id after ','")
        else:
            break
    attrs = op_attrs(p, opname)
    p.skip_inline_ws()
    p.expect(":")
    p.skip_inline_ws()
    ty = p.tensor_type()
    # Optional `// scope/path` trailer, to end of line.
    p.skip_inline_ws()
    scope_id = 0
    if p.rest().startswith("//"):
        p.bump()
        p.bump()
        p.skip_inline_ws()
        path = []
        while (c := p.peek()) is not None and c != "\n":
            path.append(c)
            p.bump()
        path = "".join(path).rstrip()
        if not path:
            p.fail("empty scope path after '//'")
        scope_id = prog.intern_scope(path)
    prog.nodes.append((OP_TAGS[opname], attrs, inputs, ty, scope_id))


# ---- encoding (mirrors binary.rs::Enc) ------------------------------------


def enc_str(s: str) -> bytes:
    b = s.encode("utf-8")
    return struct.pack("<I", len(b)) + b


def enc_usizes(xs) -> bytes:
    return struct.pack("<I", len(xs)) + b"".join(struct.pack("<Q", x) for x in xs)


def enc_ty(ty) -> bytes:
    dtype_tag, dims = ty
    out = struct.pack("<B", dtype_tag) + struct.pack("<I", len(dims))
    return out + b"".join(struct.pack("<q", d) for d in dims)


def encode_program(prog: Program) -> bytes:
    e = [enc_str(prog.name), struct.pack("<I", len(prog.scopes))]
    e += [enc_str(s) for s in prog.scopes]
    e.append(struct.pack("<I", len(prog.args)))
    for name, kind_tag, scope_id, ty in prog.args:
        e.append(enc_str(name))
        e.append(struct.pack("<B", kind_tag))
        e.append(struct.pack("<I", scope_id))
        e.append(enc_ty(ty))
    e.append(struct.pack("<I", len(prog.nodes)))
    for op_tag, attrs, inputs, ty, scope_id in prog.nodes:
        e.append(struct.pack("<B", op_tag))
        e.append(attrs)
        e.append(struct.pack("<I", len(inputs)))
        e += [struct.pack("<I", v) for v in inputs]
        e.append(enc_ty(ty))
        e.append(struct.pack("<I", scope_id))
    e.append(struct.pack("<I", len(prog.outputs)))
    e += [struct.pack("<I", v) for v in prog.outputs]
    payload = b"".join(e)
    header = (
        MAGIC
        + struct.pack("<H", FORMAT_VERSION)
        + struct.pack("<H", KIND_PROGRAM)
        + struct.pack("<Q", len(payload))
        + struct.pack("<Q", fnv64(payload))
        + b"\x00" * 8
    )
    return header + payload


def main(argv) -> int:
    check = "--check" in argv
    files = [a for a in argv if not a.startswith("--")]
    if not files:
        root = pathlib.Path(__file__).resolve().parent.parent
        files = sorted(str(p) for p in (root / "configs" / "corpus").glob("*.pir"))
    if not files:
        print("pallas_bin: no input files", file=sys.stderr)
        return 2
    failures = 0
    for f in files:
        src = pathlib.Path(f).read_text()
        try:
            blob = encode_program(parse_program(src))
        except ParseError as e:
            print(f"{f}: {e}", file=sys.stderr)
            return 2
        out = pathlib.Path(f).with_suffix(".pbp")
        if check:
            if not out.exists():
                print(f"{f}: MISSING golden {out}")
                failures += 1
            elif out.read_bytes() != blob:
                print(f"{f}: golden {out} is STALE (re-run pallas_bin.py)")
                failures += 1
            else:
                print(f"{f}: golden in sync ({len(blob)} bytes)")
        else:
            out.write_bytes(blob)
            print(f"wrote {out} ({len(blob)} bytes)")
    return 1 if failures else 0


if __name__ == "__main__":
    # Sanity-pin the FNV vectors binary.rs pins (util/hash.rs).
    assert fnv64(b"") == 0xCBF29CE484222325
    assert fnv64(b"a") == 0xAF63DC4C8601EC8C
    assert fnv64(b"foobar") == 0x85944171F73967E8
    sys.exit(main(sys.argv[1:]))
