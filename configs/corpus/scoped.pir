func @scoped(%arg0: tensor<4x8xf32> {input, name = "x"}, %arg1: tensor<8x8xf32> {param, name = "enc/dense_0/w", scope = "enc/dense_0"}, %arg2: tensor<8xf32> {param, name = "enc/dense_0/b", scope = "enc/dense_0"})
    -> (tensor<4x8xf32>) {
  %0 = dot %arg0, %arg1 {batch = []x[], contract = [1]x[0]} : tensor<4x8xf32>  // enc/dense_0
  %1 = broadcast_in_dim %arg2 {broadcast_dims = [1]} : tensor<4x8xf32>  // enc/dense_0
  %2 = add %0, %1 : tensor<4x8xf32>  // enc/dense_0
  %3 = tanh %2 : tensor<4x8xf32>  // enc/act
  return %3
}
