func @scalars(%arg0: tensor<f32> {input, name = "s"}, %arg1: tensor<2x3xi32> {const, name = "m"}, %arg2: tensor<2x3xf32> {opt_state, name = "adam.m"})
    -> (tensor<6xi32>, tensor<2x3xf32>) {
  %0 = broadcast_in_dim %arg0 {broadcast_dims = []} : tensor<2x3xf32>
  %1 = reshape %arg1 : tensor<6xi32>
  %2 = mul %0, %arg2 : tensor<2x3xf32>
  return %1, %2
}
