func @linear(%arg0: tensor<8x16xf32> {input, name = "x"}, %arg1: tensor<16x64xf32> {param, name = "w"}, %arg2: tensor<64xf32> {param, name = "b"})
    -> (tensor<8x64xf32>) {
  %0 = dot %arg0, %arg1 {batch = []x[], contract = [1]x[0]} : tensor<8x64xf32>
  %1 = broadcast_in_dim %arg2 {broadcast_dims = [1]} : tensor<8x64xf32>
  %2 = add %0, %1 : tensor<8x64xf32>
  return %2
}
