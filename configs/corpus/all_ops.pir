func @all_ops(%arg0: tensor<4x8xf32> {input, name = "x"}, %arg1: tensor<4x8xf32> {input, name = "y"}, %arg2: tensor<10x8xf32> {param, name = "table"}, %arg3: tensor<6xi32> {input, name = "ids"}, %arg4: tensor<6x8xf32> {input, name = "data"})
    -> (tensor<40xf32>, tensor<10x4xf32>, tensor<6x8xf32>, tensor<5x8xf32>, tensor<10xf32>) {
  %0 = const {value = 1.5} : tensor<4x8xf32>
  %1 = iota {dim = 1} : tensor<4x8xf32>
  %2 = add %arg0, %arg1 : tensor<4x8xf32>
  %3 = sub %2, %0 : tensor<4x8xf32>
  %4 = mul %3, %1 : tensor<4x8xf32>
  %5 = div %4, %0 : tensor<4x8xf32>
  %6 = max %5, %arg0 : tensor<4x8xf32>
  %7 = min %6, %arg1 : tensor<4x8xf32>
  %8 = neg %7 : tensor<4x8xf32>
  %9 = exp %8 : tensor<4x8xf32>
  %10 = log %9 : tensor<4x8xf32>
  %11 = tanh %10 : tensor<4x8xf32>
  %12 = abs %11 : tensor<4x8xf32>
  %13 = sqrt %12 : tensor<4x8xf32>
  %14 = rsqrt %12 : tensor<4x8xf32>
  %15 = compare %13, %14 {dir = Lt} : tensor<4x8xi1>
  %16 = select %15, %13, %14 : tensor<4x8xf32>
  %17 = convert %16 : tensor<4x8xbf16>
  %18 = convert %17 : tensor<4x8xf32>
  %19 = dot %18, %arg2 {batch = []x[], contract = [1]x[1]} : tensor<4x10xf32>
  %20 = reduce_sum %19 {dims = [1]} : tensor<4xf32>
  %21 = reduce_max %19 {dims = [0]} : tensor<10xf32>
  %22 = broadcast_in_dim %20 {broadcast_dims = [0]} : tensor<4x10xf32>
  %23 = reshape %22 : tensor<40xf32>
  %24 = transpose %19 {perm = [1, 0]} : tensor<10x4xf32>
  %25 = gather %arg2, %arg3 : tensor<6x8xf32>
  %26 = segment_sum %arg4, %arg3 {num = 5} : tensor<5x8xf32>
  return %23, %24, %25, %26, %21
}
