func @zero_arg()
    -> (tensor<4xf32>, tensor<4xf32>) {
  %0 = const {value = 2.5} : tensor<4xf32>
  %1 = iota {dim = 0} : tensor<4xf32>
  %2 = add %0, %1 : tensor<4xf32>
  return %2, %0
}
