func @pipeline(%arg0: tensor<8x16xf32> {input, name = "x"}, %arg1: tensor<16x32xf32> {param, name = "w1"}, %arg2: tensor<32x32xf32> {param, name = "w2"}, %arg3: tensor<32x16xf32> {param, name = "w3"}, %arg4: tensor<16x8xf32> {param, name = "w4"})
    -> (tensor<8x8xf32>) {
  %0 = dot %arg0, %arg1 {batch = []x[], contract = [1]x[0]} : tensor<8x32xf32>
  %1 = tanh %0 : tensor<8x32xf32>
  %2 = dot %1, %arg2 {batch = []x[], contract = [1]x[0]} : tensor<8x32xf32>
  %3 = tanh %2 : tensor<8x32xf32>
  %4 = dot %3, %arg3 {batch = []x[], contract = [1]x[0]} : tensor<8x16xf32>
  %5 = tanh %4 : tensor<8x16xf32>
  %6 = dot %5, %arg4 {batch = []x[], contract = [1]x[0]} : tensor<8x8xf32>
  return %6
}
